//! SLO health plane and anomaly flight recorder.
//!
//! Two cooperating pieces, both shared by the daemon and the router:
//!
//! * [`HealthPlane`] — rolling-window availability and p99-latency SLOs
//!   with multi-window burn rates, computed lazily from cumulative
//!   counter/histogram snapshots (the caller feeds one
//!   [`HealthSample`] per read, the plane diffs against the ring).
//!   Surfaced by the `health` protocol op and the `swaphi_slo_*` /
//!   `swaphi_burn_rate` Prometheus families.
//! * [`FlightRecorder`] — trips on configured anomalies (backend marked
//!   dead, deadline-exceeded burst, partial-response streak) and
//!   atomically dumps one self-contained JSON bundle (span ring +
//!   metrics snapshot + slow-query ring + fleet/tune state) to
//!   `--flight-dir`, ring-limited to K bundles on disk.
//!
//! The plane is deliberately decoupled from `metrics::Registry`: it
//! consumes plain snapshots, so the router (whose error accounting
//! differs from the daemon's) feeds it the same way the daemon does.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt::Write as _;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::util::json::Json;

/// SLO targets. Availability is a success-fraction target in (0, 1);
/// the latency SLO is a p99 bound in microseconds.
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Availability target, e.g. 0.999 ("three nines").
    pub availability: f64,
    /// p99 latency target in microseconds.
    pub p99_us: u64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig { availability: 0.999, p99_us: 2_000_000 }
    }
}

/// The burn-rate windows, shortest first. Short by server standards —
/// this is a request-scale service whose CI run lasts seconds, so the
/// windows are seconds-to-minutes rather than hours; the multi-window
/// *structure* (fast window catches bursts, slow window catches slow
/// bleeds) is the standard SRE shape.
pub const WINDOWS: &[(&str, u64)] = &[("30s", 30), ("5m", 300), ("30m", 1800)];

/// Burn rate at which a warn becomes critical (error budget consumed
/// eight times faster than sustainable).
const CRITICAL_BURN: f64 = 8.0;
/// p99/target ratio past which latency is critical.
const CRITICAL_LATENCY_RATIO: f64 = 2.0;

/// One cumulative snapshot of the request counters feeding the SLOs.
/// `total`/`errors` are monotone counters; `lat_bounds`/`lat_counts`
/// are the latency histogram's bucket layout and per-bucket counts
/// (also monotone), so windowed distributions fall out of a diff.
#[derive(Clone, Debug)]
pub struct HealthSample {
    /// Monotonic timestamp, microseconds (the trace recorder's clock).
    pub t_us: u64,
    /// Requests answered, success or failure.
    pub total: u64,
    /// Error responses (the availability SLO's numerator).
    pub errors: u64,
    /// Latency histogram bucket upper bounds (exclusive), ascending.
    pub lat_bounds: Vec<u64>,
    /// Per-bucket counts, one longer than `lat_bounds` (overflow last).
    pub lat_counts: Vec<u64>,
    /// Observed latency maximum, the +Inf-bucket quantile fallback.
    pub lat_max: u64,
}

/// SLO verdict, ordered by severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    Ok,
    Warn,
    Critical,
}

impl Verdict {
    pub fn as_str(self) -> &'static str {
        match self {
            Verdict::Ok => "ok",
            Verdict::Warn => "warn",
            Verdict::Critical => "critical",
        }
    }

    /// Numeric form for the `swaphi_slo_health` gauge (0/1/2).
    pub fn as_level(self) -> u64 {
        match self {
            Verdict::Ok => 0,
            Verdict::Warn => 1,
            Verdict::Critical => 2,
        }
    }
}

/// One window's worth of one SLO's status.
#[derive(Clone, Debug)]
pub struct WindowStatus {
    pub window: &'static str,
    /// Requests observed in the window.
    pub total: u64,
    /// The SLO's measured value in the window: error fraction for
    /// availability, p99 microseconds for latency.
    pub value: f64,
    /// Budget burn rate: 1.0 = consuming exactly the allowed budget.
    pub burn: f64,
}

/// One SLO's full status across all windows.
#[derive(Clone, Debug)]
pub struct SloStatus {
    pub slo: &'static str,
    pub target: f64,
    pub verdict: Verdict,
    pub windows: Vec<WindowStatus>,
}

/// The whole health plane's answer.
#[derive(Clone, Debug)]
pub struct HealthReport {
    pub verdict: Verdict,
    pub slos: Vec<SloStatus>,
}

impl HealthReport {
    /// The `slos` array of the `health` op's response.
    pub fn detail_json(&self) -> Json {
        Json::Arr(
            self.slos
                .iter()
                .map(|s| {
                    let mut m = BTreeMap::new();
                    m.insert("slo".to_string(), Json::Str(s.slo.to_string()));
                    m.insert("target".to_string(), Json::Num(s.target));
                    m.insert("verdict".to_string(), Json::Str(s.verdict.as_str().to_string()));
                    m.insert(
                        "windows".to_string(),
                        Json::Arr(
                            s.windows
                                .iter()
                                .map(|w| {
                                    let mut wm = BTreeMap::new();
                                    wm.insert(
                                        "window".to_string(),
                                        Json::Str(w.window.to_string()),
                                    );
                                    wm.insert("total".to_string(), Json::Num(w.total as f64));
                                    wm.insert("value".to_string(), Json::Num(w.value));
                                    wm.insert("burn".to_string(), Json::Num(w.burn));
                                    Json::Obj(wm)
                                })
                                .collect(),
                        ),
                    );
                    Json::Obj(m)
                })
                .collect(),
        )
    }
}

/// Rolling-window SLO evaluation over a ring of cumulative snapshots.
///
/// Reads are where the work happens: [`HealthPlane::report`] pushes the
/// fresh sample, prunes the ring past the longest window, and diffs the
/// newest sample against the oldest sample inside each window. Between
/// reads the plane costs nothing — no background thread, no per-request
/// work beyond the counters the server already keeps.
pub struct HealthPlane {
    cfg: SloConfig,
    ring: Mutex<VecDeque<HealthSample>>,
}

impl HealthPlane {
    pub fn new(cfg: SloConfig) -> Self {
        HealthPlane { cfg, ring: Mutex::new(VecDeque::new()) }
    }

    pub fn config(&self) -> SloConfig {
        self.cfg
    }

    /// Evaluate the SLOs given the freshest cumulative sample.
    pub fn report(&self, sample: HealthSample) -> HealthReport {
        let now = sample.t_us;
        let longest = WINDOWS.iter().map(|&(_, s)| s).max().unwrap_or(0) * 1_000_000;
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(sample);
        // keep one sample older than the longest window as its baseline
        while ring.len() > 2
            && ring[1].t_us + longest < now
        {
            ring.pop_front();
        }
        let newest = ring.back().expect("just pushed").clone();

        let mut availability_windows = Vec::with_capacity(WINDOWS.len());
        let mut latency_windows = Vec::with_capacity(WINDOWS.len());
        for &(name, secs) in WINDOWS {
            let horizon = now.saturating_sub(secs * 1_000_000);
            // the youngest sample at or before the horizon baselines the
            // window; absent one (young process), the window starts empty
            let base = ring
                .iter()
                .rev()
                .find(|s| s.t_us <= horizon)
                .cloned()
                .unwrap_or_else(|| ring.front().expect("nonempty").clone());
            let total = newest.total.saturating_sub(base.total);
            let errors = newest.errors.saturating_sub(base.errors);
            let error_frac = if total == 0 { 0.0 } else { errors as f64 / total as f64 };
            let burn = error_frac / (1.0 - self.cfg.availability).max(1e-9);
            availability_windows.push(WindowStatus {
                window: name,
                total,
                value: error_frac,
                burn,
            });
            let p99 = windowed_p99(&base, &newest);
            let lat_burn = if p99 == 0 { 0.0 } else { p99 as f64 / self.cfg.p99_us as f64 };
            latency_windows.push(WindowStatus {
                window: name,
                total,
                value: p99 as f64,
                burn: lat_burn,
            });
        }
        drop(ring);

        let availability_verdict = availability_windows
            .iter()
            .filter(|w| w.total > 0)
            .map(|w| {
                if w.burn >= CRITICAL_BURN {
                    Verdict::Critical
                } else if w.burn >= 1.0 {
                    Verdict::Warn
                } else {
                    Verdict::Ok
                }
            })
            .max()
            .unwrap_or(Verdict::Ok);
        let latency_verdict = latency_windows
            .iter()
            .filter(|w| w.total > 0)
            .map(|w| {
                if w.burn >= CRITICAL_LATENCY_RATIO {
                    Verdict::Critical
                } else if w.burn > 1.0 {
                    Verdict::Warn
                } else {
                    Verdict::Ok
                }
            })
            .max()
            .unwrap_or(Verdict::Ok);

        let slos = vec![
            SloStatus {
                slo: "availability",
                target: self.cfg.availability,
                verdict: availability_verdict,
                windows: availability_windows,
            },
            SloStatus {
                slo: "p99_latency",
                target: self.cfg.p99_us as f64,
                verdict: latency_verdict,
                windows: latency_windows,
            },
        ];
        let verdict = slos.iter().map(|s| s.verdict).max().unwrap_or(Verdict::Ok);
        HealthReport { verdict, slos }
    }

    /// Append the `swaphi_slo_*` / `swaphi_burn_rate` families to a
    /// Prometheus text exposition, given a just-computed report.
    pub fn prometheus_append(&self, out: &mut String, report: &HealthReport) {
        let _ = writeln!(out, "# HELP swaphi_slo_availability_target availability SLO target (success fraction)");
        let _ = writeln!(out, "# TYPE swaphi_slo_availability_target gauge");
        let _ = writeln!(out, "swaphi_slo_availability_target {}", fmt_f64(self.cfg.availability));
        let _ = writeln!(out, "# HELP swaphi_slo_p99_target_microseconds p99 latency SLO target");
        let _ = writeln!(out, "# TYPE swaphi_slo_p99_target_microseconds gauge");
        let _ = writeln!(out, "swaphi_slo_p99_target_microseconds {}", self.cfg.p99_us);
        let _ = writeln!(out, "# HELP swaphi_slo_health SLO verdict (0 ok, 1 warn, 2 critical)");
        let _ = writeln!(out, "# TYPE swaphi_slo_health gauge");
        let _ = writeln!(out, "swaphi_slo_health {}", report.verdict.as_level());
        let _ = writeln!(out, "# HELP swaphi_burn_rate error-budget burn rate per SLO and window (1.0 = at budget)");
        let _ = writeln!(out, "# TYPE swaphi_burn_rate gauge");
        for s in &report.slos {
            for w in &s.windows {
                let _ = writeln!(
                    out,
                    "swaphi_burn_rate{{slo=\"{}\",window=\"{}\"}} {}",
                    s.slo,
                    w.window,
                    fmt_f64(w.burn)
                );
            }
        }
    }
}

/// p99 of the latency distribution accumulated between two cumulative
/// samples (bucket-wise count diff, then the histogram quantile walk).
fn windowed_p99(base: &HealthSample, newest: &HealthSample) -> u64 {
    if base.lat_bounds != newest.lat_bounds {
        // layout changed under us (never happens in-process); fall back
        // to the newest cumulative distribution
        return quantile_of(&newest.lat_bounds, &newest.lat_counts, newest.lat_max, 0.99);
    }
    let diff: Vec<u64> = newest
        .lat_counts
        .iter()
        .zip(base.lat_counts.iter().chain(std::iter::repeat(&0)))
        .map(|(n, b)| n.saturating_sub(*b))
        .collect();
    quantile_of(&newest.lat_bounds, &diff, newest.lat_max, 0.99)
}

fn quantile_of(bounds: &[u64], counts: &[u64], max: u64, q: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((q * total as f64).ceil() as u64).max(1);
    let mut acc = 0;
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return if i < bounds.len() { bounds[i] } else { max };
        }
    }
    max
}

fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Flight-recorder triggers — which anomaly tripped a bundle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Anomaly {
    /// A cluster backend was marked dead (router only).
    BackendDead,
    /// A burst of deadline-exceeded responses.
    DeadlineBurst,
    /// A streak of partial (degraded) routed responses.
    PartialStreak,
}

impl Anomaly {
    pub fn as_str(self) -> &'static str {
        match self {
            Anomaly::BackendDead => "backend_dead",
            Anomaly::DeadlineBurst => "deadline_burst",
            Anomaly::PartialStreak => "partial_streak",
        }
    }
}

/// Deadline-burst threshold: this many `deadline_exceeded` responses
/// inside [`BURST_WINDOW_US`] trips a bundle.
const BURST_THRESHOLD: usize = 5;
const BURST_WINDOW_US: u64 = 10_000_000;
/// Partial-response streak that trips a bundle.
const STREAK_THRESHOLD: u64 = 3;
/// Global bundle cooldown: anomalies landing inside this window after a
/// bundle was written are recorded in the trigger state but do not dump
/// again — one incident, one bundle.
const COOLDOWN_US: u64 = 60_000_000;

/// Anomaly-triggered crash-dump ring. Disabled (all methods no-ops)
/// without a directory. Bundles are written atomically (`.tmp` +
/// rename) and pruned oldest-first past `max_bundles`.
pub struct FlightRecorder {
    dir: Option<PathBuf>,
    max_bundles: usize,
    state: Mutex<RecorderState>,
}

#[derive(Default)]
struct RecorderState {
    seq: u64,
    written: u64,
    last_bundle_us: Option<u64>,
    /// Partitions whose death already produced a bundle; re-armed on
    /// recovery.
    dead_partitions: BTreeSet<usize>,
    deadline_hits: VecDeque<u64>,
    partial_streak: u64,
    /// The current partial streak already produced a bundle.
    streak_tripped: bool,
}

impl FlightRecorder {
    pub fn new(dir: Option<PathBuf>, max_bundles: usize) -> Self {
        FlightRecorder {
            dir,
            max_bundles: max_bundles.max(1),
            state: Mutex::new(RecorderState::default()),
        }
    }

    /// A recorder that never writes (the default when `--flight-dir` is
    /// not given).
    pub fn disabled() -> Self {
        FlightRecorder::new(None, 1)
    }

    pub fn is_enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// Bundles written over this process's lifetime.
    pub fn bundles_written(&self) -> u64 {
        self.state.lock().unwrap().written
    }

    /// A backend was marked dead. Trips once per partition until
    /// [`backend_recovered`](Self::backend_recovered) re-arms it.
    /// `body` builds the bundle payload only when a dump happens.
    pub fn backend_dead(&self, now_us: u64, partition: usize, body: &dyn Fn() -> Json) {
        if !self.is_enabled() {
            return;
        }
        let armed = {
            let mut st = self.state.lock().unwrap();
            st.dead_partitions.insert(partition)
        };
        if armed {
            self.trip(now_us, Anomaly::BackendDead, &format!("partition {partition} marked dead"), body);
        }
    }

    /// A dead backend answered again; its death trigger re-arms.
    pub fn backend_recovered(&self, partition: usize) {
        if !self.is_enabled() {
            return;
        }
        self.state.lock().unwrap().dead_partitions.remove(&partition);
    }

    /// One deadline-exceeded response; trips on a burst.
    pub fn deadline_exceeded(&self, now_us: u64, body: &dyn Fn() -> Json) {
        if !self.is_enabled() {
            return;
        }
        let burst = {
            let mut st = self.state.lock().unwrap();
            st.deadline_hits.push_back(now_us);
            while st
                .deadline_hits
                .front()
                .is_some_and(|&t| t + BURST_WINDOW_US < now_us)
            {
                st.deadline_hits.pop_front();
            }
            if st.deadline_hits.len() >= BURST_THRESHOLD {
                st.deadline_hits.clear();
                true
            } else {
                false
            }
        };
        if burst {
            self.trip(
                now_us,
                Anomaly::DeadlineBurst,
                &format!("{BURST_THRESHOLD}+ deadline_exceeded within {}s", BURST_WINDOW_US / 1_000_000),
                body,
            );
        }
    }

    /// One routed response's degradation state. A streak of
    /// [`STREAK_THRESHOLD`] consecutive partial responses trips once;
    /// a complete response resets the streak.
    pub fn partial_response(&self, now_us: u64, partial: bool, body: &dyn Fn() -> Json) {
        if !self.is_enabled() {
            return;
        }
        let tripped = {
            let mut st = self.state.lock().unwrap();
            if !partial {
                st.partial_streak = 0;
                st.streak_tripped = false;
                false
            } else {
                st.partial_streak += 1;
                if st.partial_streak >= STREAK_THRESHOLD && !st.streak_tripped {
                    st.streak_tripped = true;
                    true
                } else {
                    false
                }
            }
        };
        if tripped {
            self.trip(
                now_us,
                Anomaly::PartialStreak,
                &format!("{STREAK_THRESHOLD} consecutive partial responses"),
                body,
            );
        }
    }

    /// Write one bundle unless inside the cooldown window.
    fn trip(&self, now_us: u64, anomaly: Anomaly, detail: &str, body: &dyn Fn() -> Json) {
        let Some(dir) = &self.dir else { return };
        let seq = {
            let mut st = self.state.lock().unwrap();
            if st
                .last_bundle_us
                .is_some_and(|t| now_us.saturating_sub(t) < COOLDOWN_US)
            {
                return;
            }
            st.last_bundle_us = Some(now_us);
            st.seq += 1;
            st.written += 1;
            st.seq
        };
        let captured_at = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut m = BTreeMap::new();
        m.insert("reason".to_string(), Json::Str(anomaly.as_str().to_string()));
        m.insert("detail".to_string(), Json::Str(detail.to_string()));
        m.insert("captured_at_unix".to_string(), Json::Num(captured_at as f64));
        m.insert("captured_at_us".to_string(), Json::Num(now_us as f64));
        m.insert("body".to_string(), body());
        let doc = Json::Obj(m).to_string();

        let name = format!("flight-{seq:06}-{}.json", anomaly.as_str());
        let path = dir.join(&name);
        let tmp = dir.join(format!(".{name}.tmp"));
        let write = std::fs::create_dir_all(dir)
            .and_then(|_| std::fs::write(&tmp, doc.as_bytes()))
            .and_then(|_| std::fs::rename(&tmp, &path));
        if let Err(e) = write {
            eprintln!("flight recorder: cannot write {}: {e}", path.display());
            let _ = std::fs::remove_file(&tmp);
            return;
        }
        self.prune(dir);
    }

    /// Drop the oldest bundles past the ring limit (lexicographic order
    /// == write order: the sequence number is zero-padded).
    fn prune(&self, dir: &PathBuf) {
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        let mut bundles: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("flight-") && n.ends_with(".json"))
            })
            .collect();
        bundles.sort();
        while bundles.len() > self.max_bundles {
            let _ = std::fs::remove_file(bundles.remove(0));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_us: u64, total: u64, errors: u64, lat: &[u64]) -> HealthSample {
        // cumulative exponential histogram over the supplied values
        let bounds: Vec<u64> = (0..20).map(|k| 1u64 << k).collect();
        let mut counts = vec![0u64; bounds.len() + 1];
        let mut max = 0;
        for &v in lat {
            let idx = bounds.partition_point(|&b| b <= v);
            counts[idx] += 1;
            max = max.max(v);
        }
        HealthSample { t_us, total, errors, lat_bounds: bounds, lat_counts: counts, lat_max: max }
    }

    #[test]
    fn healthy_traffic_is_ok() {
        let plane = HealthPlane::new(SloConfig { availability: 0.999, p99_us: 1_000_000 });
        let r = plane.report(sample(1_000_000, 100, 0, &[500, 700, 900]));
        assert_eq!(r.verdict, Verdict::Ok);
        assert_eq!(r.slos.len(), 2);
        assert!(r.slos.iter().all(|s| s.verdict == Verdict::Ok));
    }

    #[test]
    fn empty_windows_are_ok_not_nan() {
        let plane = HealthPlane::new(SloConfig::default());
        let r = plane.report(sample(0, 0, 0, &[]));
        assert_eq!(r.verdict, Verdict::Ok);
        for s in &r.slos {
            for w in &s.windows {
                assert!(w.burn.is_finite());
                assert_eq!(w.total, 0);
            }
        }
    }

    #[test]
    fn error_burst_burns_the_budget() {
        let plane = HealthPlane::new(SloConfig { availability: 0.999, p99_us: 1_000_000 });
        plane.report(sample(1_000_000, 100, 0, &[]));
        // 10% errors in the 30s window = burn 100 >> critical
        let r = plane.report(sample(2_000_000, 200, 10, &[]));
        assert_eq!(r.verdict, Verdict::Critical);
        let avail = &r.slos[0];
        assert_eq!(avail.slo, "availability");
        assert_eq!(avail.verdict, Verdict::Critical);
        assert!(avail.windows[0].burn > CRITICAL_BURN, "{:?}", avail.windows[0]);
    }

    #[test]
    fn latency_slo_uses_windowed_p99() {
        let plane = HealthPlane::new(SloConfig { availability: 0.5, p99_us: 1_000 });
        // old traffic was fast...
        plane.report(sample(1_000_000, 10, 0, &[100; 10]));
        // ...new traffic is slow; windowed p99 must see only the diff
        let mut lat: Vec<u64> = vec![100; 10];
        lat.extend([900_000u64; 10]);
        let r = plane.report(sample(2_000_000, 20, 0, &lat));
        let latency = &r.slos[1];
        assert_eq!(latency.slo, "p99_latency");
        assert_eq!(latency.verdict, Verdict::Critical, "{latency:?}");
        assert!(latency.windows[0].value >= 900_000.0, "{:?}", latency.windows[0]);
    }

    #[test]
    fn burn_recovers_as_the_window_slides() {
        let plane = HealthPlane::new(SloConfig { availability: 0.9, p99_us: 1_000_000 });
        plane.report(sample(1_000_000, 100, 0, &[]));
        let r = plane.report(sample(2_000_000, 200, 50, &[]));
        assert_ne!(r.verdict, Verdict::Ok);
        // 40 minutes later, all windows have slid past the errors and
        // fresh traffic is clean
        let r = plane.report(sample(2_400_000_000, 1200, 50, &[]));
        let r2 = plane.report(sample(2_401_000_000, 1300, 50, &[]));
        assert_eq!(r.verdict, Verdict::Ok, "{:?}", r.slos[0]);
        assert_eq!(r2.verdict, Verdict::Ok);
    }

    #[test]
    fn detail_json_shape() {
        let plane = HealthPlane::new(SloConfig::default());
        let r = plane.report(sample(1_000_000, 10, 0, &[100]));
        let j = r.detail_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].str_field("slo").unwrap(), "availability");
        assert_eq!(arr[0].str_field("verdict").unwrap(), "ok");
        let windows = arr[0].get("windows").and_then(Json::as_arr).unwrap();
        assert_eq!(windows.len(), WINDOWS.len());
        assert_eq!(windows[0].str_field("window").unwrap(), "30s");
        assert!(windows[0].get("burn").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn prometheus_families_render() {
        let plane = HealthPlane::new(SloConfig { availability: 0.999, p99_us: 2_000_000 });
        let r = plane.report(sample(1_000_000, 10, 0, &[100]));
        let mut out = String::new();
        plane.prometheus_append(&mut out, &r);
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines.contains(&"# TYPE swaphi_slo_availability_target gauge"));
        assert!(lines.contains(&"swaphi_slo_availability_target 0.999"));
        assert!(lines.contains(&"swaphi_slo_p99_target_microseconds 2000000"));
        assert!(lines.contains(&"swaphi_slo_health 0"));
        assert!(lines.contains(&"# TYPE swaphi_burn_rate gauge"));
        assert!(out.contains("swaphi_burn_rate{slo=\"availability\",window=\"30s\"}"));
        assert!(out.contains("swaphi_burn_rate{slo=\"p99_latency\",window=\"30m\"}"));
        // every sample line parses as `name[{labels}] value`
        for line in lines.iter().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').unwrap();
            assert!(value.parse::<f64>().is_ok(), "{line}");
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join("swaphi-health-tests")
            .join(format!("{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn bundles_in(dir: &PathBuf) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| e.file_name().into_string().ok())
                    .filter(|n| n.starts_with("flight-"))
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    #[test]
    fn disabled_recorder_never_writes() {
        let r = FlightRecorder::disabled();
        assert!(!r.is_enabled());
        r.backend_dead(0, 1, &|| Json::Null);
        r.deadline_exceeded(0, &|| Json::Null);
        r.partial_response(0, true, &|| Json::Null);
        assert_eq!(r.bundles_written(), 0);
    }

    #[test]
    fn backend_death_trips_once_until_recovery() {
        let dir = tmp_dir("dead-once");
        let r = FlightRecorder::new(Some(dir.clone()), 8);
        let body = || Json::Str("state".to_string());
        r.backend_dead(1_000, 2, &body);
        r.backend_dead(2_000, 2, &body);
        assert_eq!(r.bundles_written(), 1, "second death of the same partition is silent");
        let names = bundles_in(&dir);
        assert_eq!(names.len(), 1);
        assert!(names[0].contains("backend_dead"), "{names:?}");
        let doc = Json::parse(&std::fs::read_to_string(dir.join(&names[0])).unwrap()).unwrap();
        assert_eq!(doc.str_field("reason").unwrap(), "backend_dead");
        assert!(doc.str_field("detail").unwrap().contains("partition 2"));
        assert_eq!(doc.str_field("body").unwrap(), "state");
        // recovery re-arms; a fresh death (past cooldown) dumps again
        r.backend_recovered(2);
        r.backend_dead(1_000 + COOLDOWN_US, 2, &body);
        assert_eq!(r.bundles_written(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cooldown_suppresses_cascading_bundles() {
        let dir = tmp_dir("cooldown");
        let r = FlightRecorder::new(Some(dir.clone()), 8);
        let body = || Json::Null;
        r.backend_dead(1_000, 0, &body);
        // the dead backend makes every routed answer partial; the streak
        // trigger fires inside the cooldown and must not double-dump
        for i in 0..5 {
            r.partial_response(2_000 + i, true, &body);
        }
        assert_eq!(r.bundles_written(), 1, "one incident, one bundle");
        assert_eq!(bundles_in(&dir).len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deadline_burst_trips_and_ring_prunes() {
        let dir = tmp_dir("burst");
        let r = FlightRecorder::new(Some(dir.clone()), 2);
        let body = || Json::Null;
        // below threshold: silent
        for i in 0..BURST_THRESHOLD - 1 {
            r.deadline_exceeded(i as u64 * 1_000, &body);
        }
        assert_eq!(r.bundles_written(), 0);
        r.deadline_exceeded(5_000, &body);
        assert_eq!(r.bundles_written(), 1);
        // two more bursts, each past the cooldown: the 2-bundle ring
        // keeps only the newest two on disk
        for burst in 1..3u64 {
            let t0 = burst * (COOLDOWN_US + 1_000_000);
            for i in 0..BURST_THRESHOLD {
                r.deadline_exceeded(t0 + i as u64, &body);
            }
        }
        assert_eq!(r.bundles_written(), 3);
        let names = bundles_in(&dir);
        assert_eq!(names.len(), 2, "{names:?}");
        assert!(names.iter().all(|n| n.contains("deadline_burst")));
        assert!(names[0].contains("flight-000002"), "oldest pruned: {names:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn partial_streak_resets_on_complete_response() {
        let dir = tmp_dir("streak");
        let r = FlightRecorder::new(Some(dir.clone()), 8);
        let body = || Json::Null;
        r.partial_response(1, true, &body);
        r.partial_response(2, true, &body);
        r.partial_response(3, false, &body); // streak broken
        r.partial_response(4, true, &body);
        r.partial_response(5, true, &body);
        assert_eq!(r.bundles_written(), 0);
        r.partial_response(6, true, &body);
        assert_eq!(r.bundles_written(), 1);
        // the still-running streak does not dump again
        r.partial_response(7 + COOLDOWN_US, true, &body);
        assert_eq!(r.bundles_written(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn verdict_ordering_and_levels() {
        assert!(Verdict::Ok < Verdict::Warn);
        assert!(Verdict::Warn < Verdict::Critical);
        assert_eq!(Verdict::Ok.as_level(), 0);
        assert_eq!(Verdict::Warn.as_str(), "warn");
        assert_eq!(Verdict::Critical.as_level(), 2);
    }
}

//! FASTA reading and writing.
//!
//! A streaming, allocation-conscious FASTA parser sufficient for protein
//! database ingestion: handles `>` headers (id = first whitespace-delimited
//! token), multi-line sequences, CRLF, lower-case residues, `*`/ambiguity
//! codes, blank lines, and missing trailing newline. Writer wraps at 60
//! columns like the classic tools.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

/// One FASTA record (raw ASCII residues, un-encoded).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    /// First whitespace-delimited token of the header line.
    pub id: String,
    /// Remainder of the header line (may be empty).
    pub description: String,
    /// Sequence letters with whitespace stripped.
    pub seq: Vec<u8>,
}

impl Record {
    pub fn new(id: impl Into<String>, seq: impl Into<Vec<u8>>) -> Self {
        Record { id: id.into(), description: String::new(), seq: seq.into() }
    }

    pub fn len(&self) -> usize {
        self.seq.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seq.is_empty()
    }
}

/// Streaming FASTA reader over any `BufRead`.
pub struct Reader<R: BufRead> {
    inner: R,
    pending_header: Option<String>,
    line_no: usize,
}

impl Reader<BufReader<std::fs::File>> {
    /// Open a FASTA file from disk.
    pub fn from_path(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let f = std::fs::File::open(path.as_ref()).map_err(|e| {
            anyhow::anyhow!("open FASTA {}: {e}", path.as_ref().display())
        })?;
        Ok(Reader::new(BufReader::new(f)))
    }
}

impl<R: Read> Reader<BufReader<R>> {
    /// Wrap any reader.
    pub fn from_reader(r: R) -> Self {
        Reader::new(BufReader::new(r))
    }
}

impl<R: BufRead> Reader<R> {
    pub fn new(inner: R) -> Self {
        Reader { inner, pending_header: None, line_no: 0 }
    }

    fn read_line(&mut self, buf: &mut String) -> anyhow::Result<usize> {
        buf.clear();
        let n = self.inner.read_line(buf)?;
        if n > 0 {
            self.line_no += 1;
        }
        // strip newline / CR
        while buf.ends_with('\n') || buf.ends_with('\r') {
            buf.pop();
        }
        Ok(n)
    }

    /// Read the next record, or `None` at end of input.
    pub fn next_record(&mut self) -> anyhow::Result<Option<Record>> {
        let mut line = String::new();
        // find the header
        let header = loop {
            if let Some(h) = self.pending_header.take() {
                break h;
            }
            let n = self.read_line(&mut line)?;
            if n == 0 {
                return Ok(None);
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(rest) = trimmed.strip_prefix('>') {
                break rest.to_string();
            }
            anyhow::bail!("line {}: expected '>' header, got {trimmed:?}", self.line_no);
        };
        let (id, description) = match header.split_once(char::is_whitespace) {
            Some((id, rest)) => (id.to_string(), rest.trim().to_string()),
            None => (header.clone(), String::new()),
        };
        // accumulate sequence lines until next header / EOF
        let mut seq = Vec::new();
        loop {
            let n = self.read_line(&mut line)?;
            if n == 0 {
                break;
            }
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            if let Some(rest) = trimmed.strip_prefix('>') {
                self.pending_header = Some(rest.to_string());
                break;
            }
            seq.extend(trimmed.bytes().filter(|b| !b.is_ascii_whitespace()));
        }
        Ok(Some(Record { id, description, seq }))
    }

    /// Read all remaining records.
    pub fn read_all(&mut self) -> anyhow::Result<Vec<Record>> {
        let mut out = Vec::new();
        while let Some(rec) = self.next_record()? {
            out.push(rec);
        }
        Ok(out)
    }
}

impl<R: BufRead> Iterator for Reader<R> {
    type Item = anyhow::Result<Record>;
    fn next(&mut self) -> Option<Self::Item> {
        self.next_record().transpose()
    }
}

/// Parse a full FASTA byte buffer.
pub fn parse(bytes: &[u8]) -> anyhow::Result<Vec<Record>> {
    Reader::from_reader(bytes).read_all()
}

/// Write records in 60-column FASTA format.
pub fn write<W: Write>(w: &mut W, records: &[Record]) -> anyhow::Result<()> {
    for rec in records {
        if rec.description.is_empty() {
            writeln!(w, ">{}", rec.id)?;
        } else {
            writeln!(w, ">{} {}", rec.id, rec.description)?;
        }
        for chunk in rec.seq.chunks(60) {
            w.write_all(chunk)?;
            writeln!(w)?;
        }
    }
    Ok(())
}

/// Write records to a file path.
pub fn write_path(path: impl AsRef<Path>, records: &[Record]) -> anyhow::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write(&mut f, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_simple_records() {
        let data = b">sp|P1|TEST first protein\nMKTAYIA\nKQRQIS\n>P2\nARNDC\n";
        let recs = parse(data).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].id, "sp|P1|TEST");
        assert_eq!(recs[0].description, "first protein");
        assert_eq!(recs[0].seq, b"MKTAYIAKQRQIS".to_vec());
        assert_eq!(recs[1].id, "P2");
        assert_eq!(recs[1].description, "");
        assert_eq!(recs[1].seq, b"ARNDC".to_vec());
    }

    #[test]
    fn handles_crlf_and_blank_lines() {
        let data = b">a\r\nMK\r\n\r\nTA\r\n\n>b\r\nRR\r\n";
        let recs = parse(data).unwrap();
        assert_eq!(recs[0].seq, b"MKTA".to_vec());
        assert_eq!(recs[1].seq, b"RR".to_vec());
    }

    #[test]
    fn missing_trailing_newline() {
        let recs = parse(b">x\nMKV").unwrap();
        assert_eq!(recs[0].seq, b"MKV".to_vec());
    }

    #[test]
    fn empty_input_yields_nothing() {
        assert!(parse(b"").unwrap().is_empty());
        assert!(parse(b"\n\n").unwrap().is_empty());
    }

    #[test]
    fn garbage_before_header_is_an_error() {
        assert!(parse(b"MKV\n>x\nA\n").is_err());
    }

    #[test]
    fn empty_sequence_record_allowed() {
        let recs = parse(b">empty\n>full\nMK\n").unwrap();
        assert_eq!(recs.len(), 2);
        assert!(recs[0].is_empty());
        assert_eq!(recs[1].seq, b"MK".to_vec());
    }

    #[test]
    fn roundtrip_write_parse() {
        let recs = vec![
            Record { id: "a".into(), description: "desc here".into(), seq: vec![b'M'; 130] },
            Record::new("b", b"ARNDCQEGH".to_vec()),
        ];
        let mut buf = Vec::new();
        write(&mut buf, &recs).unwrap();
        let back = parse(&buf).unwrap();
        assert_eq!(back, recs);
    }

    #[test]
    fn wraps_at_60_columns() {
        let recs = vec![Record::new("long", vec![b'A'; 125])];
        let mut buf = Vec::new();
        write(&mut buf, &recs).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4); // header + 60 + 60 + 5
        assert_eq!(lines[1].len(), 60);
        assert_eq!(lines[3].len(), 5);
    }

    #[test]
    fn iterator_interface() {
        let data = b">a\nMK\n>b\nAR\n>c\nND\n";
        let ids: Vec<String> =
            Reader::from_reader(&data[..]).map(|r| r.unwrap().id).collect();
        assert_eq!(ids, vec!["a", "b", "c"]);
    }
}

//! Performance metrics: GCUPS accounting, wall timers, simple histograms.
//!
//! GCUPS (billion cell updates per second) is the paper's headline metric:
//! `cells = query_length × Σ subject_lengths` (real lengths, not padded —
//! padding work is overhead, not useful cells, exactly as the paper counts
//! it), divided by elapsed seconds.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cell-update accounting for one search run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Cells(pub u128);

impl Cells {
    /// Cells for aligning one query of length `qlen` against subjects
    /// totalling `db_residues`.
    pub fn for_search(qlen: usize, db_residues: u128) -> Cells {
        Cells(qlen as u128 * db_residues)
    }

    pub fn add(&mut self, other: Cells) {
        self.0 += other.0;
    }

    /// GCUPS given elapsed seconds.
    pub fn gcups(&self, seconds: f64) -> f64 {
        crate::util::gcups(self.0, seconds)
    }
}

/// Precision-tier accounting for one search (or a batch): how many
/// subject alignments ran in each tier and how many narrow-tier lanes
/// saturated and were rescored at full precision. The rescore fraction
/// is the quantity the Xeon Phi simulator charges for the narrow tier's
/// second pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RescoreStats {
    /// Subject alignments scored in the narrow (i16) tier.
    pub i16_lanes: u64,
    /// Narrow-tier alignments that saturated and were rescored at i32.
    pub overflowed: u64,
    /// Subject alignments scored directly at full (i32) precision.
    pub i32_lanes: u64,
}

impl RescoreStats {
    pub fn add(&mut self, other: RescoreStats) {
        self.i16_lanes += other.i16_lanes;
        self.overflowed += other.overflowed;
        self.i32_lanes += other.i32_lanes;
    }

    /// Fraction of narrow-tier alignments that needed an i32 rescore
    /// (0.0 when the narrow tier wasn't used).
    pub fn rescore_fraction(&self) -> f64 {
        if self.i16_lanes == 0 {
            0.0
        } else {
            self.overflowed as f64 / self.i16_lanes as f64
        }
    }

    /// Share of all alignments that ran in the narrow tier.
    pub fn narrow_share(&self) -> f64 {
        let total = self.i16_lanes + self.i32_lanes;
        if total == 0 {
            0.0
        } else {
            self.i16_lanes as f64 / total as f64
        }
    }
}

/// Prefilter-funnel accounting for one query (or a batch): how many
/// subjects entered the seeded prefilter, how many survived to the exact
/// SW rescore, and the heuristic work spent deciding. The survivor
/// fraction is the quantity the funnel's cost model charges the exact
/// stage for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefilterStats {
    /// Subjects screened by the prefilter (the whole database, per query).
    pub candidates: u64,
    /// Subjects that survived to the exact SW rescore.
    pub survivors: u64,
    /// Seed word hits streamed through the two-hit diagonal filter.
    pub word_hits: u64,
    /// Two-hit triggers extended.
    pub triggers: u64,
    /// DP cells the heuristic actually visited (ungapped + gapped).
    pub cells_visited: u64,
}

impl PrefilterStats {
    pub fn add(&mut self, other: PrefilterStats) {
        self.candidates += other.candidates;
        self.survivors += other.survivors;
        self.word_hits += other.word_hits;
        self.triggers += other.triggers;
        self.cells_visited += other.cells_visited;
    }

    /// Fraction of screened subjects fed to the exact stage (0.0 when
    /// nothing was screened).
    pub fn survivor_fraction(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            self.survivors as f64 / self.candidates as f64
        }
    }
}

/// Report-stage accounting for one query (or a batch): how many hit
/// pairs went through the bounded-memory traceback, how many exceeded
/// the cell cap and degraded to coordinates-only, and the DP cells the
/// stage visited (full-matrix or linear passes alike).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TracebackStats {
    /// Hit pairs re-aligned by the report stage.
    pub pairs: u64,
    /// Pairs whose DP matrix exceeded the cell cap (coordinates-only).
    pub capped: u64,
    /// DP cells visited by the stage.
    pub cells: u64,
}

impl TracebackStats {
    pub fn add(&mut self, other: TracebackStats) {
        self.pairs += other.pairs;
        self.capped += other.capped;
        self.cells += other.cells;
    }
}

/// Wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Fixed-bucket histogram for latency/length distributions.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Bucket upper bounds (exclusive), ascending; a final overflow bucket
    /// catches the rest.
    bounds: Vec<u64>,
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    max: u64,
}

impl Histogram {
    /// Build with the given ascending bucket upper bounds.
    pub fn new(bounds: Vec<u64>) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        let n = bounds.len() + 1;
        Histogram { bounds, counts: vec![0; n], total: 0, sum: 0, max: 0 }
    }

    /// Exponential bounds 2^k covering [1, limit].
    pub fn exponential(limit: u64) -> Self {
        let mut bounds = Vec::new();
        let mut b = 1u64;
        while b <= limit {
            bounds.push(b);
            b = b.saturating_mul(2);
        }
        Histogram::new(bounds)
    }

    pub fn record(&mut self, v: u64) {
        let idx = self.bounds.partition_point(|&b| b <= v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Mean of all recorded values; 0.0 (not NaN) for an empty histogram.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile from bucket boundaries (upper bound of the
    /// bucket holding the q-th sample). Total on all inputs: an empty
    /// histogram yields 0, `q` is clamped into [0, 1], and a NaN `q` is
    /// treated as 0.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return if i < self.bounds.len() { self.bounds[i] } else { self.max };
            }
        }
        self.max
    }

    /// Bucket upper bounds (exclusive), ascending — the Prometheus
    /// exposition's `le` boundaries.
    pub fn bounds(&self) -> &[u64] {
        &self.bounds
    }

    /// Per-bucket counts; one longer than [`bounds`](Self::bounds)
    /// (the final overflow bucket).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Sum of all recorded values.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Fold `other` into `self`: bucket-wise count addition, totals and
    /// sums added, max of maxes. Both histograms must have been built
    /// with the same bucket bounds — merging is how per-thread shard
    /// histograms fold into the fleet's histogram at the batch barrier,
    /// and shards of one metric always share a layout.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(
            self.bounds, other.bounds,
            "Histogram::merge requires identical bucket bounds"
        );
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// One-line summary row (count/mean/max/p50/p99) — what the server's
    /// stats endpoint reports per histogram.
    pub fn summary(&self) -> HistogramSummary {
        HistogramSummary {
            count: self.total,
            mean: self.mean(),
            max: self.max,
            p50: self.quantile(0.5),
            p99: self.quantile(0.99),
        }
    }
}

/// Snapshot of a [`Histogram`]'s headline statistics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean: f64,
    pub max: u64,
    pub p50: u64,
    pub p99: u64,
}

/// Per-query result row of a benchmark run — what the figure harnesses
/// print and EXPERIMENTS.md records.
#[derive(Clone, Debug)]
pub struct QueryPerf {
    pub query_id: String,
    pub query_len: usize,
    pub cells: Cells,
    pub seconds: f64,
    pub best_score: i32,
}

impl QueryPerf {
    pub fn gcups(&self) -> f64 {
        self.cells.gcups(self.seconds)
    }
}

/// Mean and max GCUPS over a set of per-query rows (how the paper reports
/// "average and maximum performance").
pub fn summarize(rows: &[QueryPerf]) -> (f64, f64) {
    if rows.is_empty() {
        return (0.0, 0.0);
    }
    let mean = rows.iter().map(|r| r.gcups()).sum::<f64>() / rows.len() as f64;
    let max = rows.iter().map(|r| r.gcups()).fold(0.0, f64::max);
    (mean, max)
}

/// Monotonic counter handle. Registered once in a [`Registry`], then
/// updated with one relaxed atomic op in hot paths — the registry is
/// only consulted again at snapshot/exposition time.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-value gauge (f64, stored as bits). Same discipline as
/// [`Counter`]: registered once, set with one atomic store.
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge(AtomicU64::new(0f64.to_bits()))
    }
}

/// A registry-owned histogram. Recording takes the mutex, so hot paths
/// should shard into per-thread [`Histogram`]s and fold them here via
/// [`Histogram::merge`] at a barrier.
pub type SharedHistogram = Arc<Mutex<Histogram>>;

enum MetricCell {
    Counter(Arc<Counter>),
    /// Counters of one family split by a label, e.g.
    /// `swaphi_errors_total{code="overloaded"}`. Kept sorted by label
    /// value for stable exposition output.
    Labeled { label_key: &'static str, cells: Vec<(String, Arc<Counter>)> },
    Gauge(Arc<Gauge>),
    Histogram(SharedHistogram),
}

struct MetricEntry {
    name: String,
    help: String,
    cell: MetricCell,
}

impl MetricEntry {
    fn kind(&self) -> &'static str {
        match self.cell {
            MetricCell::Counter(_) | MetricCell::Labeled { .. } => "counter",
            MetricCell::Gauge(_) => "gauge",
            MetricCell::Histogram(_) => "histogram",
        }
    }
}

/// Named counter/gauge/histogram registry: the single source of truth
/// behind both the `stats` op (shape-compatible JSON) and the `metrics`
/// op (Prometheus text exposition).
///
/// Registration is idempotent — registering an existing name returns
/// the existing handle, so the server, tests and warmup code can all
/// ask for `swaphi_batches_total` without coordinating. Updates go
/// through the returned `Arc` handles and never touch the registry
/// lock.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<MetricEntry>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            match &e.cell {
                MetricCell::Counter(c) => return Arc::clone(c),
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let c = Arc::new(Counter::default());
        entries.push(MetricEntry {
            name: name.to_string(),
            help: help.to_string(),
            cell: MetricCell::Counter(Arc::clone(&c)),
        });
        c
    }

    /// A counter in a labeled family: `name{label_key="label_value"}`.
    /// The family shares one HELP/TYPE block; each distinct label value
    /// gets its own cell.
    pub fn labeled_counter(
        &self,
        name: &str,
        help: &str,
        label_key: &'static str,
        label_value: &str,
    ) -> Arc<Counter> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter_mut().find(|e| e.name == name) {
            match &mut e.cell {
                MetricCell::Labeled { label_key: lk, cells } => {
                    assert_eq!(*lk, label_key, "metric {name:?} label key mismatch");
                    if let Some((_, c)) = cells.iter().find(|(v, _)| v == label_value) {
                        return Arc::clone(c);
                    }
                    let c = Arc::new(Counter::default());
                    cells.push((label_value.to_string(), Arc::clone(&c)));
                    cells.sort_by(|a, b| a.0.cmp(&b.0));
                    return c;
                }
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let c = Arc::new(Counter::default());
        entries.push(MetricEntry {
            name: name.to_string(),
            help: help.to_string(),
            cell: MetricCell::Labeled {
                label_key,
                cells: vec![(label_value.to_string(), Arc::clone(&c))],
            },
        });
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            match &e.cell {
                MetricCell::Gauge(g) => return Arc::clone(g),
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let g = Arc::new(Gauge::default());
        entries.push(MetricEntry {
            name: name.to_string(),
            help: help.to_string(),
            cell: MetricCell::Gauge(Arc::clone(&g)),
        });
        g
    }

    /// Register a histogram with the given initial (empty) layout.
    pub fn histogram(&self, name: &str, help: &str, layout: Histogram) -> SharedHistogram {
        let mut entries = self.entries.lock().unwrap();
        if let Some(e) = entries.iter().find(|e| e.name == name) {
            match &e.cell {
                MetricCell::Histogram(h) => return Arc::clone(h),
                _ => panic!("metric {name:?} already registered with a different kind"),
            }
        }
        let h = Arc::new(Mutex::new(layout));
        entries.push(MetricEntry {
            name: name.to_string(),
            help: help.to_string(),
            cell: MetricCell::Histogram(Arc::clone(&h)),
        });
        h
    }

    /// Snapshot of every labeled-counter cell in family `name`, as
    /// `(label_value, count)` pairs sorted by label value.
    pub fn labeled_snapshot(&self, name: &str) -> Vec<(String, u64)> {
        let entries = self.entries.lock().unwrap();
        match entries.iter().find(|e| e.name == name).map(|e| &e.cell) {
            Some(MetricCell::Labeled { cells, .. }) => {
                cells.iter().map(|(v, c)| (v.clone(), c.get())).collect()
            }
            _ => Vec::new(),
        }
    }

    /// Render the whole registry in the Prometheus text exposition
    /// format (version 0.0.4): `# HELP` / `# TYPE` per family, then one
    /// sample line per cell; histograms expand to cumulative
    /// `_bucket{le=...}` samples plus `_sum` and `_count`.
    pub fn prometheus_text(&self) -> String {
        let entries = self.entries.lock().unwrap();
        let mut out = String::new();
        for e in entries.iter() {
            let _ = writeln!(out, "# HELP {} {}", e.name, e.help);
            let _ = writeln!(out, "# TYPE {} {}", e.name, e.kind());
            match &e.cell {
                MetricCell::Counter(c) => {
                    let _ = writeln!(out, "{} {}", e.name, c.get());
                }
                MetricCell::Labeled { label_key, cells } => {
                    for (value, c) in cells {
                        let _ = writeln!(out, "{}{{{}=\"{}\"}} {}", e.name, label_key, value, c.get());
                    }
                }
                MetricCell::Gauge(g) => {
                    let _ = writeln!(out, "{} {}", e.name, fmt_f64(g.get()));
                }
                MetricCell::Histogram(h) => {
                    let h = h.lock().unwrap();
                    let mut cum = 0u64;
                    for (i, &count) in h.counts().iter().enumerate() {
                        cum += count;
                        let le = match h.bounds().get(i) {
                            Some(b) => b.to_string(),
                            None => "+Inf".to_string(),
                        };
                        let _ = writeln!(out, "{}_bucket{{le=\"{}\"}} {}", e.name, le, cum);
                    }
                    let _ = writeln!(out, "{}_sum {}", e.name, h.sum());
                    let _ = writeln!(out, "{}_count {}", e.name, h.count());
                }
            }
        }
        out
    }
}

/// Prometheus-friendly float rendering: integral values print without
/// a fraction, everything else with full precision.
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_for_search() {
        let c = Cells::for_search(100, 1_000_000);
        assert_eq!(c.0, 100_000_000);
        assert!((c.gcups(0.1) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rescore_stats_fractions() {
        let mut a = RescoreStats { i16_lanes: 90, overflowed: 9, i32_lanes: 10 };
        assert!((a.rescore_fraction() - 0.1).abs() < 1e-12);
        assert!((a.narrow_share() - 0.9).abs() < 1e-12);
        a.add(RescoreStats { i16_lanes: 10, overflowed: 1, i32_lanes: 0 });
        assert_eq!(a.i16_lanes, 100);
        assert_eq!(a.overflowed, 10);
        assert!((a.rescore_fraction() - 0.1).abs() < 1e-12);
        assert_eq!(RescoreStats::default().rescore_fraction(), 0.0);
        assert_eq!(RescoreStats::default().narrow_share(), 0.0);
    }

    #[test]
    fn prefilter_stats_fractions() {
        let mut p = PrefilterStats {
            candidates: 200,
            survivors: 20,
            word_hits: 900,
            triggers: 40,
            cells_visited: 5_000,
        };
        assert!((p.survivor_fraction() - 0.1).abs() < 1e-12);
        p.add(PrefilterStats { candidates: 200, survivors: 60, ..Default::default() });
        assert_eq!(p.candidates, 400);
        assert_eq!(p.survivors, 80);
        assert!((p.survivor_fraction() - 0.2).abs() < 1e-12);
        assert_eq!(PrefilterStats::default().survivor_fraction(), 0.0);
    }

    #[test]
    fn traceback_stats_accumulate() {
        let mut t = TracebackStats { pairs: 10, capped: 1, cells: 5_000 };
        t.add(TracebackStats { pairs: 5, capped: 0, cells: 2_500 });
        assert_eq!(t, TracebackStats { pairs: 15, capped: 1, cells: 7_500 });
        assert_eq!(TracebackStats::default().pairs, 0);
    }

    #[test]
    fn histogram_buckets() {
        let mut h = Histogram::new(vec![10, 100, 1000]);
        for v in [1, 5, 50, 500, 5000, 9] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max(), 5000);
        assert!((h.mean() - (1.0 + 5.0 + 50.0 + 500.0 + 5000.0 + 9.0) / 6.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_exponential_covers() {
        let h = Histogram::exponential(1024);
        assert_eq!(h.bounds.len(), 11); // 1,2,4,...,1024
    }

    #[test]
    fn empty_histogram_is_total() {
        // no division by zero, no bogus quantiles: every accessor is
        // well-defined before the first record()
        let h = Histogram::exponential(1024);
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert!(!h.mean().is_nan());
        assert_eq!(h.max(), 0);
        for q in [0.0, 0.5, 0.99, 1.0, -3.0, 7.0, f64::NAN] {
            assert_eq!(h.quantile(q), 0, "q={q}");
        }
        let s = h.summary();
        assert_eq!((s.count, s.max, s.p50, s.p99), (0, 0, 0, 0));
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_bucket_histogram() {
        // Histogram::new(vec![]) is legal: everything lands in the one
        // overflow bucket and quantiles degrade to the observed max
        let mut h = Histogram::new(vec![]);
        assert_eq!(h.quantile(0.5), 0, "still empty");
        h.record(41);
        assert_eq!(h.count(), 1);
        assert_eq!(h.mean(), 41.0);
        assert_eq!(h.max(), 41);
        assert_eq!(h.quantile(0.0), 41);
        assert_eq!(h.quantile(0.5), 41);
        assert_eq!(h.quantile(1.0), 41);
        h.record(7);
        assert_eq!(h.quantile(0.5), 41, "one bucket cannot resolve finer");
        assert_eq!(h.mean(), 24.0);
    }

    #[test]
    fn quantile_clamps_and_rejects_nan() {
        let mut h = Histogram::new(vec![10, 100]);
        for v in [1, 2, 50] {
            h.record(v);
        }
        assert_eq!(h.quantile(-1.0), h.quantile(0.0));
        assert_eq!(h.quantile(2.0), h.quantile(1.0));
        assert_eq!(h.quantile(f64::NAN), h.quantile(0.0));
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = Histogram::exponential(1 << 20);
        for v in 0..1000u64 {
            h.record(v * 7);
        }
        assert!(h.quantile(0.5) <= h.quantile(0.9));
        assert!(h.quantile(0.9) <= h.quantile(1.0));
    }

    #[test]
    fn summarize_mean_max() {
        let rows = vec![
            QueryPerf {
                query_id: "a".into(),
                query_len: 10,
                cells: Cells(1_000_000_000),
                seconds: 1.0,
                best_score: 1,
            },
            QueryPerf {
                query_id: "b".into(),
                query_len: 10,
                cells: Cells(3_000_000_000),
                seconds: 1.0,
                best_score: 2,
            },
        ];
        let (mean, max) = summarize(&rows);
        assert!((mean - 2.0).abs() < 1e-9);
        assert!((max - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zero() {
        assert_eq!(summarize(&[]), (0.0, 0.0));
    }

    #[test]
    fn merge_is_identity_on_empty_rhs() {
        let mut h = Histogram::new(vec![10, 100]);
        for v in [1, 50, 500] {
            h.record(v);
        }
        let before = (h.counts().to_vec(), h.count(), h.sum(), h.max());
        h.merge(&Histogram::new(vec![10, 100]));
        assert_eq!((h.counts().to_vec(), h.count(), h.sum(), h.max()), before);
        // and merging *into* an empty histogram reproduces the source
        let mut empty = Histogram::new(vec![10, 100]);
        let mut src = Histogram::new(vec![10, 100]);
        for v in [1, 50, 500] {
            src.record(v);
        }
        empty.merge(&src);
        assert_eq!(empty.counts(), src.counts());
        assert_eq!(empty.count(), src.count());
        assert_eq!(empty.sum(), src.sum());
        assert_eq!(empty.max(), src.max());
    }

    #[test]
    fn merge_commutes_and_equals_single_stream() {
        // merging per-thread shards must be indistinguishable from one
        // thread having recorded everything, in either merge order
        let values_a = [1u64, 7, 64, 900, 3];
        let values_b = [2u64, 2000, 8, 8, 77];
        let layout = || Histogram::exponential(1 << 12);
        let mut a = layout();
        let mut b = layout();
        let mut all = layout();
        for v in values_a {
            a.record(v);
            all.record(v);
        }
        for v in values_b {
            b.record(v);
            all.record(v);
        }
        let mut ab = layout();
        ab.merge(&a);
        ab.merge(&b);
        let mut ba = layout();
        ba.merge(&b);
        ba.merge(&a);
        for merged in [&ab, &ba] {
            assert_eq!(merged.counts(), all.counts());
            assert_eq!(merged.count(), all.count());
            assert_eq!(merged.sum(), all.sum());
            assert_eq!(merged.max(), all.max());
            assert_eq!(merged.quantile(0.5), all.quantile(0.5));
        }
    }

    #[test]
    fn merge_of_two_empties_stays_empty() {
        let mut a = Histogram::exponential(16);
        a.merge(&Histogram::exponential(16));
        assert!(a.is_empty());
        assert_eq!(a.summary().count, 0);
    }

    #[test]
    #[should_panic(expected = "identical bucket bounds")]
    fn merge_rejects_mismatched_layouts() {
        let mut a = Histogram::new(vec![10]);
        a.merge(&Histogram::new(vec![10, 100]));
    }

    #[test]
    fn registry_registration_is_idempotent() {
        let r = Registry::new();
        let a = r.counter("swaphi_batches_total", "batches");
        let b = r.counter("swaphi_batches_total", "batches");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g1 = r.gauge("swaphi_queue_depth", "depth");
        let g2 = r.gauge("swaphi_queue_depth", "depth");
        g1.set(4.5);
        assert_eq!(g2.get(), 4.5);
        let h1 = r.histogram("swaphi_batch_size", "sizes", Histogram::exponential(64));
        let h2 = r.histogram("swaphi_batch_size", "sizes", Histogram::exponential(64));
        h1.lock().unwrap().record(8);
        assert_eq!(h2.lock().unwrap().count(), 1);
    }

    #[test]
    fn labeled_counters_share_a_family() {
        let r = Registry::new();
        let over = r.labeled_counter("swaphi_errors_total", "errors by code", "code", "overloaded");
        let bad = r.labeled_counter("swaphi_errors_total", "errors by code", "code", "bad_request");
        let over2 = r.labeled_counter("swaphi_errors_total", "errors by code", "code", "overloaded");
        over.inc();
        over2.inc();
        bad.inc();
        assert_eq!(
            r.labeled_snapshot("swaphi_errors_total"),
            vec![("bad_request".to_string(), 1), ("overloaded".to_string(), 2)]
        );
        assert!(r.labeled_snapshot("no_such_family").is_empty());
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::new();
        r.counter("swaphi_admitted_total", "requests admitted").add(5);
        r.labeled_counter("swaphi_errors_total", "errors by code", "code", "overloaded").inc();
        r.gauge("swaphi_queue_depth", "admission queue depth").set(3.0);
        let h = r.histogram("swaphi_batch_size", "batch sizes", Histogram::new(vec![1, 2, 4]));
        {
            let mut h = h.lock().unwrap();
            h.record(1);
            h.record(3);
            h.record(9);
        }
        let text = r.prometheus_text();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.contains(&"# TYPE swaphi_admitted_total counter"));
        assert!(lines.contains(&"swaphi_admitted_total 5"));
        assert!(lines.contains(&"swaphi_errors_total{code=\"overloaded\"} 1"));
        assert!(lines.contains(&"# TYPE swaphi_queue_depth gauge"));
        assert!(lines.contains(&"swaphi_queue_depth 3"));
        // histogram buckets are cumulative and end at +Inf == _count
        assert!(lines.contains(&"swaphi_batch_size_bucket{le=\"1\"} 0"));
        assert!(lines.contains(&"swaphi_batch_size_bucket{le=\"2\"} 1"));
        assert!(lines.contains(&"swaphi_batch_size_bucket{le=\"4\"} 2"));
        assert!(lines.contains(&"swaphi_batch_size_bucket{le=\"+Inf\"} 3"));
        assert!(lines.contains(&"swaphi_batch_size_sum 13"));
        assert!(lines.contains(&"swaphi_batch_size_count 3"));
        // every sample line parses as `name[{labels}] value`
        for line in &lines {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line");
            assert!(!name.is_empty());
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        }
    }
}

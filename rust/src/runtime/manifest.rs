//! artifacts/manifest.json — the AOT contract between L2 and L3.
//!
//! aot.py records, for every lowered bucket, the static shapes and the
//! positional argument order; the runtime refuses to guess. Bucket
//! selection picks the smallest artifact that fits a (query length,
//! subject length) pair for a given variant.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT artifact (static-shape executable).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub variant: String,
    pub qpad: usize,
    pub lpad: usize,
    pub ns: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", path.display()))?;
        let json = Json::parse(&text)?;
        if json.str_field("format")? != "hlo-text" {
            anyhow::bail!("unsupported artifact format {:?}", json.str_field("format")?);
        }
        let mut artifacts = Vec::new();
        for entry in json
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts[]"))?
        {
            let spec = ArtifactSpec {
                name: entry.str_field("name")?.to_string(),
                file: dir.join(entry.str_field("file")?),
                variant: entry.str_field("variant")?.to_string(),
                qpad: entry.usize_field("qpad")?,
                lpad: entry.usize_field("lpad")?,
                ns: entry.usize_field("ns")?,
            };
            if !spec.file.exists() {
                anyhow::bail!("manifest references missing artifact {}", spec.file.display());
            }
            artifacts.push(spec);
        }
        if artifacts.is_empty() {
            anyhow::bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts })
    }

    /// Variants present in the manifest, deduped.
    pub fn variants(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.iter().map(|a| a.variant.as_str()).collect();
        v.sort();
        v.dedup();
        v
    }

    /// Smallest bucket of `variant` fitting a query of `qlen` and subject
    /// (padded profile) length `slen`. Minimizes wasted padded cells.
    pub fn pick(&self, variant: &str, qlen: usize, slen: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.variant == variant && a.qpad >= qlen && a.lpad >= slen)
            .min_by_key(|a| a.qpad * a.lpad)
    }

    /// Largest subject length any bucket of `variant` can take for `qlen`.
    pub fn max_lpad(&self, variant: &str, qlen: usize) -> Option<usize> {
        self.artifacts
            .iter()
            .filter(|a| a.variant == variant && a.qpad >= qlen)
            .map(|a| a.lpad)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, entries: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            format!(r#"{{"format": "hlo-text", "artifacts": [{entries}]}}"#),
        )
        .unwrap();
    }

    fn entry(name: &str, variant: &str, q: usize, l: usize, n: usize) -> String {
        format!(
            r#"{{"name":"{name}","file":"{name}.hlo.txt","variant":"{variant}","qpad":{q},"lpad":{l},"ns":{n}}}"#
        )
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("swaphi-manifest-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn loads_and_picks_smallest_fit() {
        let dir = tmp("pick");
        let entries = [
            entry("a", "inter_gather", 128, 256, 32),
            entry("b", "inter_gather", 512, 512, 32),
            entry("c", "inter_gather", 512, 2048, 32),
            entry("d", "striped", 128, 256, 16),
        ]
        .join(",");
        write_manifest(&dir, &entries);
        for n in ["a", "b", "c", "d"] {
            std::fs::write(dir.join(format!("{n}.hlo.txt")), "HloModule x").unwrap();
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 4);
        assert_eq!(m.variants(), vec!["inter_gather", "striped"]);
        assert_eq!(m.pick("inter_gather", 100, 200).unwrap().name, "a");
        assert_eq!(m.pick("inter_gather", 300, 400).unwrap().name, "b");
        assert_eq!(m.pick("inter_gather", 300, 1000).unwrap().name, "c");
        assert!(m.pick("inter_gather", 600, 100).is_none());
        assert!(m.pick("nope", 10, 10).is_none());
        assert_eq!(m.max_lpad("inter_gather", 400), Some(2048));
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn missing_artifact_file_rejected() {
        let dir = tmp("missing");
        write_manifest(&dir, &entry("gone", "x", 8, 8, 8));
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn missing_manifest_hints_make() {
        let dir = tmp("nomanifest");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn real_generated_manifest_loads() {
        // integration with the actual `make artifacts` output when present
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 3);
            assert!(m.variants().contains(&"inter_gather"));
        }
    }
}

//! PJRT runtime — loads the AOT HLO-text artifacts and executes them on
//! the request path (L3 ↔ L2 bridge).
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`, with a lazy per-artifact compile cache
//! (each bucket compiles once per process, like the paper's one-time
//! offload-region initialization per coprocessor). Python is never
//! touched at runtime: the artifacts directory is the entire contract.

pub mod manifest;

use crate::align::{ProfileAligner, QueryContext};
use crate::alphabet::{DUMMY, ROW};
use crate::db::profile::{SequenceProfile, LANES};
use crate::matrices::Scoring;
use manifest::{ArtifactSpec, Manifest};
use std::collections::HashMap;
use std::path::Path;
use std::cell::RefCell;
use std::rc::Rc;

/// A compiled-executable cache over the artifact manifest.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client over an artifacts directory.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtRuntime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Platform string of the PJRT backend (e.g. "cpu" / "Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the executable for an artifact.
    pub fn executable(&self, spec: &ArtifactSpec) -> anyhow::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&spec.name) {
            return Ok(Rc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", spec.name))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(spec.name.clone(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Number of executables compiled so far (observability / tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Execute one chunk alignment: query profile (qpad×32, row-major),
    /// subjects (ns×lpad codes), returns `ns` scores.
    ///
    /// Inputs must already match the artifact's static shapes; use
    /// [`PjrtAligner`] for the padding/marshalling logic.
    pub fn run_chunk(
        &self,
        spec: &ArtifactSpec,
        qprof: &[i32],
        subjects: &[i32],
        alpha: i32,
        beta: i32,
    ) -> anyhow::Result<Vec<i32>> {
        anyhow::ensure!(qprof.len() == spec.qpad * ROW, "qprof shape mismatch");
        anyhow::ensure!(subjects.len() == spec.ns * spec.lpad, "subjects shape mismatch");
        let exe = self.executable(spec)?;
        let qprof_lit = xla::Literal::vec1(qprof)
            .reshape(&[spec.qpad as i64, ROW as i64])
            .map_err(|e| anyhow::anyhow!("qprof literal: {e:?}"))?;
        let subj_lit = xla::Literal::vec1(subjects)
            .reshape(&[spec.ns as i64, spec.lpad as i64])
            .map_err(|e| anyhow::anyhow!("subjects literal: {e:?}"))?;
        let gaps_lit = xla::Literal::vec1(&[alpha, beta]);
        let result = exe
            .execute::<xla::Literal>(&[qprof_lit, subj_lit, gaps_lit])
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let scores = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?
            .to_vec::<i32>()
            .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
        anyhow::ensure!(scores.len() == spec.ns, "expected {} scores, got {}", spec.ns, scores.len());
        Ok(scores)
    }
}

/// Map an [`crate::align::EngineKind`]-style variant name to the artifact
/// variant naming of aot.py.
pub fn artifact_variant(kind: crate::align::EngineKind) -> &'static str {
    match kind {
        crate::align::EngineKind::InterSP => "inter_onehot",
        crate::align::EngineKind::InterQP => "inter_gather",
        crate::align::EngineKind::IntraQP => "striped",
        crate::align::EngineKind::Scalar => "inter_gather",
    }
}

/// A [`ProfileAligner`] that executes sequence profiles through the AOT
/// artifacts — the full three-layer request path.
pub struct PjrtAligner {
    runtime: Rc<PjrtRuntime>,
    variant: &'static str,
    /// scratch to avoid re-allocating the subjects tile per profile
    subjects_buf: Vec<i32>,
    qprof_buf: Vec<i32>,
    qprof_qpad: usize,
}

impl PjrtAligner {
    pub fn new(runtime: Rc<PjrtRuntime>, kind: crate::align::EngineKind) -> Self {
        PjrtAligner {
            runtime,
            variant: artifact_variant(kind),
            subjects_buf: Vec::new(),
            qprof_buf: Vec::new(),
            qprof_qpad: 0,
        }
    }

    /// Pick the bucket for this (qlen, profile length) or explain why not.
    fn pick(&self, qlen: usize, slen: usize) -> anyhow::Result<ArtifactSpec> {
        self.runtime.manifest.pick(self.variant, qlen, slen).cloned().ok_or_else(|| {
            anyhow::anyhow!(
                "no {} artifact fits qlen={qlen} slen={slen}; available: {:?}",
                self.variant,
                self.runtime
                    .manifest
                    .artifacts
                    .iter()
                    .filter(|a| a.variant == self.variant)
                    .map(|a| (a.qpad, a.lpad))
                    .collect::<Vec<_>>()
            )
        })
    }

    fn build_qprof(&mut self, ctx: &QueryContext, sc: &Scoring, qpad: usize) {
        if self.qprof_qpad == qpad && !self.qprof_buf.is_empty() {
            return; // cached for this query/bucket
        }
        // rows for real query positions, all-zero rows for DUMMY padding
        self.qprof_buf.clear();
        self.qprof_buf.resize(qpad * ROW, 0);
        for (i, &q) in ctx.codes.iter().enumerate() {
            let row = sc.row(q);
            self.qprof_buf[i * ROW..(i + 1) * ROW].copy_from_slice(row);
        }
        self.qprof_qpad = qpad;
    }
}

impl ProfileAligner for PjrtAligner {
    fn name(&self) -> &'static str {
        self.variant
    }

    fn align(
        &mut self,
        ctx: &QueryContext,
        profile: &SequenceProfile,
        sc: &Scoring,
    ) -> [i32; LANES] {
        let spec = self
            .pick(ctx.len(), profile.padded_len)
            .expect("no artifact bucket fits; regenerate artifacts with bigger buckets");
        self.build_qprof(ctx, sc, spec.qpad);
        // marshal the profile's lanes into the subjects tile, DUMMY-padded
        self.subjects_buf.clear();
        self.subjects_buf.resize(spec.ns * spec.lpad, DUMMY as i32);
        for lane in 0..profile.used {
            let len = profile.lens[lane];
            for j in 0..len {
                self.subjects_buf[lane * spec.lpad + j] = profile.vector(j)[lane] as i32;
            }
        }
        let scores = self
            .runtime
            .run_chunk(&spec, &self.qprof_buf, &self.subjects_buf, sc.gap_extend, sc.beta())
            .expect("PJRT execution failed");
        let mut out = [0i32; LANES];
        out.copy_from_slice(&scores[..LANES.min(scores.len())]);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::{search_index, EngineKind, NativeAligner};
    use crate::db::index::Index;
    use crate::db::synth::{generate, SynthSpec};

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Rc<PjrtRuntime>> {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping PJRT test: run `make artifacts` first");
            return None;
        }
        Some(Rc::new(PjrtRuntime::open(artifacts_dir()).unwrap()))
    }

    #[test]
    fn pjrt_matches_native_engines_small_db() {
        let Some(rt) = runtime() else { return };
        let db = generate(&SynthSpec::tiny(48, 33));
        let idx = Index::build(db);
        let sc = Scoring::swaphi_default();
        let q = crate::db::synth::generate_query(48, 12);
        let ctx = crate::align::QueryContext::build("q", q, &sc);

        let mut native = NativeAligner::new(EngineKind::Scalar);
        let expect = search_index(&mut native, &ctx, &idx, &sc);

        for kind in [EngineKind::InterQP, EngineKind::InterSP, EngineKind::IntraQP] {
            let mut pjrt = PjrtAligner::new(Rc::clone(&rt), kind);
            let got = search_index(&mut pjrt, &ctx, &idx, &sc);
            assert_eq!(got, expect, "pjrt {:?} vs scalar", kind);
        }
    }

    #[test]
    fn executable_cache_compiles_once() {
        let Some(rt) = runtime() else { return };
        let spec = rt.manifest.pick("inter_gather", 64, 128).unwrap().clone();
        assert_eq!(rt.compiled_count(), 0);
        rt.executable(&spec).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        rt.executable(&spec).unwrap();
        assert_eq!(rt.compiled_count(), 1);
    }

    #[test]
    fn run_chunk_validates_shapes() {
        let Some(rt) = runtime() else { return };
        let spec = rt.manifest.pick("inter_gather", 64, 128).unwrap().clone();
        let err = rt.run_chunk(&spec, &[0i32; 3], &[0i32; 3], 2, 12);
        assert!(err.is_err());
    }

    #[test]
    fn variant_mapping() {
        assert_eq!(artifact_variant(EngineKind::InterSP), "inter_onehot");
        assert_eq!(artifact_variant(EngineKind::InterQP), "inter_gather");
        assert_eq!(artifact_variant(EngineKind::IntraQP), "striped");
    }
}

//! The funnel's first stage: a batch-ready seeded prefilter over the
//! chunk plan.
//!
//! Fast mode screens every subject with the heuristic pipeline
//! ([`BlastQuery::score`]: 3-mer neighborhood seeding → two-hit diagonal
//! filter → X-drop extension) and feeds only the **survivor set** to the
//! exact SW rescore. Survivor selection is deliberately conservative:
//!
//! 1. every subject with any heuristic signal (`blast_score >= 1`)
//!    survives — the seeded recall path;
//! 2. the set is then topped up with the *longest* not-yet-surviving
//!    subjects until [`survivor_floor`] is reached — a deterministic
//!    safety net for subjects whose alignment the word seeder missed
//!    (local SW score potential grows with subject length, so length is
//!    the right seed-free prior).
//!
//! Both rules are pure functions of the scores and the (length-sorted)
//! index, so the survivor set — and therefore fast-mode output — is
//! identical for any fleet shape, mirroring the exact path's
//! scatter–gather contract.

use super::{BlastQuery, BlastStats};
use crate::db::chunk::Chunk;
use crate::db::index::Index;
use crate::matrices::Scoring;
use crate::metrics::PrefilterStats;

/// Minimum survivor count per query: `max(4·top_k, 5% of the database)`,
/// clamped to the database size. Keeps the exact stage's workload small
/// while leaving the sensitivity gate comfortable margin.
pub fn survivor_floor(top_k: usize, n_seqs: usize) -> usize {
    top_k.saturating_mul(4).max(n_seqs / 20).min(n_seqs)
}

/// Heuristically score every subject of one chunk for one compiled query,
/// appending `(seq_index, blast_score)` for each subject with signal
/// (`score > 0`) to `out` and folding the work accounting into `stats`.
/// This is the per-(query, chunk) work item the device fleet schedules —
/// the same unit as exact SW chunks.
pub fn score_chunk(
    query: &BlastQuery,
    index: &Index,
    chunk: &Chunk,
    sc: &Scoring,
    stats: &mut PrefilterStats,
    scratch: &mut Vec<i64>,
    out: &mut Vec<(usize, i32)>,
) {
    let mut bs = BlastStats::default();
    let mut candidates = 0u64;
    for p in chunk.profile_start..chunk.profile_end {
        let profile = &index.profiles[p];
        for lane in 0..profile.used {
            let seq = profile.members[lane];
            let score = query.score(&index.seqs[seq].codes, sc, &mut bs, scratch);
            candidates += 1;
            if score > 0 {
                out.push((seq, score));
            }
        }
    }
    // one fold through the shared accounting type — the same
    // PrefilterStats::add the per-thread shards, the server's metrics
    // registry (swaphi_prefilter_* counters) and the stats op all merge
    // through, so the funnel's numbers cannot drift between surfaces
    stats.add(PrefilterStats {
        candidates,
        survivors: 0,
        word_hits: bs.word_hits,
        triggers: bs.triggers,
        cells_visited: bs.cells_visited,
    });
}

/// Reduce one query's seeded hits to the final survivor set (ascending
/// sequence indices). `seeded` holds `(seq_index, blast_score)` pairs
/// from [`score_chunk`]; anything with `score >= 1` survives, then the
/// longest non-surviving subjects (highest indices — the index is
/// length-sorted ascending) top the set up to `floor`.
pub fn select_survivors(n_seqs: usize, seeded: &[(usize, i32)], floor: usize) -> Vec<usize> {
    let floor = floor.min(n_seqs);
    let mut member = vec![false; n_seqs];
    let mut count = 0usize;
    for &(seq, score) in seeded {
        if score > 0 && !member[seq] {
            member[seq] = true;
            count += 1;
        }
    }
    for seq in (0..n_seqs).rev() {
        if count >= floor {
            break;
        }
        if !member[seq] {
            member[seq] = true;
            count += 1;
        }
    }
    member
        .iter()
        .enumerate()
        .filter_map(|(i, &m)| m.then_some(i))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blast::{blast_search, BlastParams};
    use crate::db::chunk::{plan_chunks_paired, ChunkPlanConfig};
    use crate::db::synth::{generate, SynthSpec};

    #[test]
    fn floor_formula() {
        assert_eq!(survivor_floor(10, 600), 40, "4*top_k dominates small DBs");
        assert_eq!(survivor_floor(10, 10_000), 500, "5% dominates large DBs");
        assert_eq!(survivor_floor(10, 8), 8, "clamped to the database");
        assert_eq!(survivor_floor(0, 100), 5);
    }

    #[test]
    fn survivors_keep_all_seeded_and_top_up_longest() {
        // seeded hits below the floor: the longest (highest-index)
        // non-seeded subjects fill the gap, deterministically
        let got = select_survivors(10, &[(3, 5), (7, 1)], 5);
        assert_eq!(got, vec![3, 6, 7, 8, 9]);
    }

    #[test]
    fn seeded_beyond_floor_all_survive() {
        let seeded: Vec<(usize, i32)> = (0..8).map(|i| (i, 2)).collect();
        let got = select_survivors(10, &seeded, 4);
        assert_eq!(got, (0..8).collect::<Vec<_>>(), "floor is a floor, not a cap");
    }

    #[test]
    fn zero_scores_and_duplicates_are_ignored() {
        let got = select_survivors(6, &[(2, 0), (4, 3), (4, 9)], 3);
        assert_eq!(got, vec![3, 4, 5]);
    }

    #[test]
    fn floor_clamps_to_database() {
        assert_eq!(select_survivors(3, &[], 100), vec![0, 1, 2]);
        assert_eq!(select_survivors(0, &[], 5), Vec::<usize>::new());
    }

    #[test]
    fn score_chunk_matches_whole_database_blast() {
        let index = crate::db::index::Index::build(generate(&SynthSpec::tiny(60, 13)));
        let sc = crate::matrices::Scoring::swaphi_default();
        let chunks =
            plan_chunks_paired(&index, ChunkPlanConfig { target_padded_residues: 2048 });
        assert!(chunks.len() > 1, "need several chunks");
        let query_codes = index.seqs[index.n_seqs() - 1].codes.clone();
        let params = BlastParams::blastp_defaults();
        let bq = BlastQuery::build(query_codes.clone(), &sc, params);
        let mut stats = PrefilterStats::default();
        let mut scratch = Vec::new();
        let mut seeded = Vec::new();
        for chunk in &chunks {
            score_chunk(&bq, &index, chunk, &sc, &mut stats, &mut scratch, &mut seeded);
        }
        assert_eq!(stats.candidates, index.n_seqs() as u64, "every subject screened once");
        let subjects: Vec<Vec<u8>> = index.seqs.iter().map(|s| s.codes.clone()).collect();
        let (expect, bstats) = blast_search(&query_codes, &subjects, &sc, params);
        let mut dense = vec![0i32; index.n_seqs()];
        for &(seq, score) in &seeded {
            dense[seq] = score;
        }
        assert_eq!(dense, expect, "chunked scan must match the flat scan");
        assert_eq!(stats.cells_visited, bstats.cells_visited);
        assert_eq!(stats.word_hits, bstats.word_hits);
        assert!(stats.triggers > 0, "self-hit must trigger");
    }
}

//! Simplified BLAST+ (blastp) baseline substrate — the paper's Fig 7
//! heuristic comparator.
//!
//! Pipeline per subject: 3-mer neighborhood seeding (threshold T) →
//! two-hit diagonal filter → ungapped X-drop extension → gapped X-drop
//! extension. Scores are a lower bound on exhaustive SW (heuristics
//! trade sensitivity for speed); per-search statistics expose the visited
//! cell counts that make BLAST's *effective* GCUPS enormously larger and
//! query-dependent — the variance Fig 7 shows.

pub mod extend;
pub mod prefilter;
pub mod seed;

use crate::matrices::Scoring;
use extend::{gapped_extend, ungapped_extend, ExtendParams, Hsp};
use seed::{two_hit_scan, SeedParams, WordIndex};

/// Full blastp-like parameter set.
#[derive(Clone, Copy, Debug, Default)]
pub struct BlastParams {
    pub seed: SeedParams,
    pub extend: ExtendParams,
    /// Two-hit window A (blastp default 40).
    pub window: usize,
}

impl BlastParams {
    pub fn blastp_defaults() -> Self {
        BlastParams { seed: SeedParams::default(), extend: ExtendParams::default(), window: 40 }
    }
}

/// Per-search statistics (the heuristic's work accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct BlastStats {
    /// Word-index entries for the query.
    pub index_entries: usize,
    /// Word hits streamed through the diagonal filter (the seeding
    /// work real BLAST spends most of its scan time on).
    pub word_hits: u64,
    /// Two-hit triggers examined.
    pub triggers: u64,
    /// Ungapped extensions run.
    pub ungapped: u64,
    /// Gapped extensions run.
    pub gapped: u64,
    /// DP cells actually visited (ungapped + gapped).
    pub cells_visited: u64,
}

/// A query compiled for BLAST search (index built once, reused across
/// the whole database — paper Fig 2's "construct query profile" stage,
/// heuristic edition).
pub struct BlastQuery {
    pub index: WordIndex,
    pub codes: Vec<u8>,
    pub params: BlastParams,
}

impl BlastQuery {
    pub fn build(codes: Vec<u8>, sc: &Scoring, params: BlastParams) -> Self {
        let index = WordIndex::build(&codes, sc, params.seed);
        BlastQuery { index, codes, params }
    }

    /// Best heuristic score of the query vs `subject` (0 if nothing
    /// triggers — BLAST reports no hit).
    pub fn score(
        &self,
        subject: &[u8],
        sc: &Scoring,
        stats: &mut BlastStats,
        scratch: &mut Vec<i64>,
    ) -> i32 {
        stats.index_entries = self.index.entries;
        let triggers =
            two_hit_scan(&self.index, subject, self.params.window, scratch, &mut stats.word_hits);
        stats.triggers += triggers.len() as u64;
        let mut best = 0i32;
        let mut best_hsp: Option<Hsp> = None;
        for t in &triggers {
            let hsp = ungapped_extend(
                &self.codes,
                subject,
                t.qpos,
                t.spos,
                sc,
                self.params.extend.x_ungapped,
            );
            stats.ungapped += 1;
            stats.cells_visited += hsp.cells;
            if hsp.score > best_hsp.map_or(0, |h| h.score) {
                best_hsp = Some(hsp);
            }
            if hsp.score > best {
                best = hsp.score;
            }
        }
        // gapped pass on the best HSP only (blastp extends few HSPs; one
        // is enough for best-score reporting)
        if let Some(hsp) = best_hsp {
            if hsp.score >= self.params.extend.gap_trigger {
                let (g, cells) =
                    gapped_extend(&self.codes, subject, &hsp, sc, self.params.extend);
                stats.gapped += 1;
                stats.cells_visited += cells;
                best = best.max(g);
            }
        }
        best
    }
}

/// Search a whole database (sequence list), returning per-sequence scores
/// and aggregate stats.
pub fn blast_search(
    query_codes: &[u8],
    subjects: &[Vec<u8>],
    sc: &Scoring,
    params: BlastParams,
) -> (Vec<i32>, BlastStats) {
    let q = BlastQuery::build(query_codes.to_vec(), sc, params);
    let mut stats = BlastStats::default();
    let mut scratch = Vec::new();
    let scores = subjects
        .iter()
        .map(|s| q.score(s, sc, &mut stats, &mut scratch))
        .collect();
    (scores, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::scalar::sw_score;
    use crate::db::synth::{plant_homolog, rand_seq, random_codes};
    use crate::util::check::{check, prop_assert};
    use crate::util::rng::Rng;

    fn sc() -> Scoring {
        Scoring::blast_default()
    }

    #[test]
    fn finds_identical_sequence() {
        let mut rng = Rng::new(1);
        let q = random_codes(&mut rng, 60);
        let mut stats = BlastStats::default();
        let mut scratch = Vec::new();
        let bq = BlastQuery::build(q.clone(), &sc(), BlastParams::blastp_defaults());
        let score = bq.score(&q, &sc(), &mut stats, &mut scratch);
        let full = sw_score(&q, &q, &sc());
        assert!(score > 0, "self-hit must trigger");
        // self alignment is ungapped; X-drop finds (nearly) the optimum
        assert!(score >= full * 9 / 10, "blast {score} vs sw {full}");
    }

    #[test]
    fn never_exceeds_full_sw() {
        check("blast <= sw", 60, |rng| {
            let q = rand_seq(rng, 10, 80);
            let d = rand_seq(rng, 10, 80);
            let s = sc();
            let (scores, _) = blast_search(&q, &[d.clone()], &s, BlastParams::blastp_defaults());
            let full = sw_score(&q, &d, &s);
            prop_assert(scores[0] <= full, format!("blast {} > sw {full}", scores[0]))
        });
    }

    #[test]
    fn misses_weak_homology_that_sw_finds() {
        // heavily mutated planted homolog: SW always scores it; BLAST
        // sometimes misses (that's the sensitivity gap the paper's intro
        // motivates). We assert the *recall ordering* over a panel.
        let s = sc();
        let mut rng = Rng::new(7);
        let motif = random_codes(&mut rng, 30);
        let mut sw_hits = 0;
        let mut blast_hits = 0;
        let n = 40;
        let thresh = 45;
        for i in 0..n {
            let mut host = random_codes(&mut rng, 200);
            plant_homolog(&mut rng, &mut host, &motif, 0.45 + 0.01 * (i % 5) as f64);
            if sw_score(&motif, &host, &s) >= thresh {
                sw_hits += 1;
            }
            let (scores, _) =
                blast_search(&motif, &[host], &s, BlastParams::blastp_defaults());
            if scores[0] >= thresh {
                blast_hits += 1;
            }
        }
        assert!(blast_hits <= sw_hits, "blast {blast_hits} vs sw {sw_hits}");
        assert!(sw_hits > 0);
    }

    #[test]
    fn visits_far_fewer_cells_than_exhaustive() {
        let mut rng = Rng::new(9);
        let q = random_codes(&mut rng, 120);
        let subjects: Vec<Vec<u8>> = (0..50).map(|_| random_codes(&mut rng, 250)).collect();
        let total: u64 = subjects.iter().map(|s| (s.len() * q.len()) as u64).sum();
        let (_, stats) = blast_search(&q, &subjects, &sc(), BlastParams::blastp_defaults());
        assert!(
            stats.cells_visited < total / 10,
            "visited {} of {} cells",
            stats.cells_visited,
            total
        );
    }

    #[test]
    fn no_trigger_scores_zero() {
        // a subject with no residues in any neighborhood word can't hit
        let q = vec![17u8; 9]; // WWWWWWWWW
        let d = vec![0u8; 50]; // all alanine; W/A = -3, no word reaches T
        let (scores, stats) = blast_search(&q, &[d], &sc(), BlastParams::blastp_defaults());
        assert_eq!(scores[0], 0);
        assert_eq!(stats.gapped, 0);
    }

    #[test]
    fn stats_accumulate_across_subjects() {
        let mut rng = Rng::new(11);
        let q = random_codes(&mut rng, 50);
        let subjects: Vec<Vec<u8>> = (0..10).map(|_| q.clone()).collect();
        let (scores, stats) = blast_search(&q, &subjects, &sc(), BlastParams::blastp_defaults());
        assert!(scores.iter().all(|&s| s > 0));
        assert!(stats.ungapped >= 10);
        assert!(stats.cells_visited > 0);
    }
}

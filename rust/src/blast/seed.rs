//! BLAST word seeding: query 3-mer neighborhood index + subject scan.
//!
//! blastp builds, for each query position, the set of 3-letter words
//! scoring ≥ T against the query's own 3-mer under the scoring matrix
//! (the "neighborhood"), indexes them, then streams subject words through
//! the index. We implement the same with a DFS over the word space with
//! branch-and-bound pruning (prefix score + best possible remainder < T).

use crate::matrices::Scoring;

/// Word length (blastp default).
pub const K: usize = 3;

/// Number of indexable residues (the 24 real codes).
const SIGMA: usize = 24;

/// Packed code of a 3-mer.
#[inline]
pub fn pack(word: &[u8]) -> usize {
    debug_assert_eq!(word.len(), K);
    (word[0] as usize * SIGMA + word[1] as usize) * SIGMA + word[2] as usize
}

/// Seeding parameters.
#[derive(Clone, Copy, Debug)]
pub struct SeedParams {
    /// Neighborhood score threshold T (blastp default 11 for BLOSUM62).
    pub threshold: i32,
}

impl Default for SeedParams {
    fn default() -> Self {
        SeedParams { threshold: 11 }
    }
}

/// Query word index: packed 3-mer -> query positions whose neighborhood
/// contains it.
pub struct WordIndex {
    /// `buckets[code]` = list of query positions (start of the 3-mer).
    buckets: Vec<Vec<u32>>,
    /// Number of (word, position) entries (index size metric).
    pub entries: usize,
    pub qlen: usize,
}

impl WordIndex {
    /// Build the neighborhood index for `query`.
    pub fn build(query: &[u8], sc: &Scoring, params: SeedParams) -> WordIndex {
        let mut buckets = vec![Vec::new(); SIGMA * SIGMA * SIGMA];
        let mut entries = 0;
        if query.len() >= K {
            // per-position max substitution score for the bound
            let max_for: Vec<i32> = (0..SIGMA as u8)
                .map(|q| (0..SIGMA as u8).map(|w| sc.score(q, w)).max().unwrap())
                .collect();
            let mut word = [0u8; K];
            for i in 0..=query.len() - K {
                let qmer = &query[i..i + K];
                if qmer.iter().any(|&c| c as usize >= SIGMA) {
                    continue; // skip words containing padding
                }
                let bound1 = max_for[qmer[1] as usize] + max_for[qmer[2] as usize];
                let bound2 = max_for[qmer[2] as usize];
                // DFS over the 3 positions with pruning
                for w0 in 0..SIGMA as u8 {
                    let s0 = sc.score(qmer[0], w0);
                    if s0 + bound1 < params.threshold {
                        continue;
                    }
                    word[0] = w0;
                    for w1 in 0..SIGMA as u8 {
                        let s1 = s0 + sc.score(qmer[1], w1);
                        if s1 + bound2 < params.threshold {
                            continue;
                        }
                        word[1] = w1;
                        for w2 in 0..SIGMA as u8 {
                            if s1 + sc.score(qmer[2], w2) >= params.threshold {
                                word[2] = w2;
                                buckets[pack(&word)].push(i as u32);
                                entries += 1;
                            }
                        }
                    }
                }
            }
        }
        WordIndex { buckets, entries, qlen: query.len() }
    }

    /// Query positions seeded by the subject word starting at `sj`.
    #[inline]
    pub fn hits(&self, word: &[u8]) -> &[u32] {
        if word.iter().any(|&c| c as usize >= SIGMA) {
            return &[];
        }
        &self.buckets[pack(word)]
    }
}

/// A two-hit trigger: two non-overlapping word hits on the same diagonal
/// within `window` — the classic blastp heuristic gate before extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeedHit {
    /// Query position of the *second* (triggering) hit.
    pub qpos: usize,
    /// Subject position of the triggering hit.
    pub spos: usize,
}

/// Scan a subject against the index, returning two-hit triggers.
///
/// `last_hit[diag]` tracks the end of the previous hit per diagonal
/// (diag = spos − qpos + qlen so it is non-negative).
pub fn two_hit_scan(
    index: &WordIndex,
    subject: &[u8],
    window: usize,
    scratch: &mut Vec<i64>,
    word_hits: &mut u64,
) -> Vec<SeedHit> {
    let mut out = Vec::new();
    if subject.len() < K || index.qlen < K {
        return out;
    }
    let ndiag = index.qlen + subject.len();
    scratch.clear();
    scratch.resize(ndiag, i64::MIN / 2);
    for j in 0..=subject.len() - K {
        let hits = index.hits(&subject[j..j + K]);
        *word_hits += hits.len() as u64;
        for &i in hits {
            let i = i as usize;
            let diag = j + index.qlen - i;
            let last_end = scratch[diag];
            let start = j as i64;
            if start <= last_end {
                continue; // overlaps the previous hit on this diagonal: ignore
            }
            if last_end >= 0 && start - last_end <= window as i64 {
                // second non-overlapping hit within the window: trigger
                out.push(SeedHit { qpos: i, spos: j });
                scratch[diag] = i64::MIN / 2; // re-arm after trigger
            } else {
                scratch[diag] = (j + K) as i64 - 1; // end of this first hit
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::alphabet::encode;

    fn sc() -> Scoring {
        Scoring::blast_default()
    }

    #[test]
    fn identity_word_always_in_neighborhood() {
        // any 3-mer scoring >= T against itself must index itself;
        // "WWW" self-scores 33 with BLOSUM62
        let q = encode(b"WWW");
        let idx = WordIndex::build(&q, &sc(), SeedParams::default());
        assert_eq!(idx.hits(&q), &[0]);
    }

    #[test]
    fn low_scoring_self_word_excluded_when_below_t() {
        // "AAA" self-scores 12 >= 11, still included; with T=13 excluded
        let q = encode(b"AAA");
        let idx = WordIndex::build(&q, &sc(), SeedParams { threshold: 13 });
        assert_eq!(idx.hits(&q), &[] as &[u32]);
    }

    #[test]
    fn neighborhood_members_meet_threshold() {
        let s = sc();
        let q = encode(b"MKWVLAAR");
        let params = SeedParams::default();
        let idx = WordIndex::build(&q, &s, params);
        // exhaustively verify: every indexed (word, pos) scores >= T, and
        // every >= T pair is indexed
        let mut found = 0;
        for w0 in 0..24u8 {
            for w1 in 0..24u8 {
                for w2 in 0..24u8 {
                    let word = [w0, w1, w2];
                    let positions = idx.hits(&word);
                    for i in 0..=q.len() - K {
                        let score: i32 =
                            (0..K).map(|t| s.score(q[i + t], word[t])).sum();
                        let indexed = positions.contains(&(i as u32));
                        assert_eq!(
                            indexed,
                            score >= params.threshold,
                            "word {word:?} pos {i} score {score}"
                        );
                        if indexed {
                            found += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(found, idx.entries);
        assert!(found > 0);
    }

    #[test]
    fn short_query_empty_index() {
        let q = encode(b"MK");
        let idx = WordIndex::build(&q, &sc(), SeedParams::default());
        assert_eq!(idx.entries, 0);
    }

    #[test]
    fn two_hit_requires_same_diagonal_within_window() {
        let s = sc();
        // query with two W-rich words far apart on the same diagonal
        let q = encode(b"WWWAAAAAAWCWC");
        let d = encode(b"WWWAAAAAAWCWC"); // identical -> many same-diag hits
        let idx = WordIndex::build(&q, &s, SeedParams::default());
        let mut scratch = Vec::new();
        let mut wh = 0u64;
        let hits = two_hit_scan(&idx, &d, 40, &mut scratch, &mut wh);
        assert!(!hits.is_empty());
        // a subject with no repeated neighborhood words in-window yields none
        let far = encode(b"WWW");
        let hits2 = two_hit_scan(&idx, &far, 40, &mut scratch, &mut wh);
        assert!(hits2.is_empty(), "single word cannot two-hit: {hits2:?}");
    }

    #[test]
    fn two_hit_window_enforced() {
        let s = sc();
        // two identical words separated by more than the window on the
        // same diagonal must NOT trigger with a small window
        let spacer = vec![b'A'; 60];
        let mut seq = b"WCW".to_vec();
        seq.extend_from_slice(&spacer);
        seq.extend_from_slice(b"WCW");
        let q = encode(&seq);
        let idx = WordIndex::build(&q, &s, SeedParams::default());
        let mut scratch = Vec::new();
        let mut wh = 0u64;
        let near = two_hit_scan(&idx, &q, 100, &mut scratch, &mut wh);
        assert!(!near.is_empty());
        let strict = two_hit_scan(&idx, &q, 10, &mut scratch, &mut wh);
        // the far pair no longer triggers on its diagonal; any remaining
        // triggers must be within 10 of a previous hit
        assert!(strict.len() <= near.len());
    }
}

//! BLAST hit extension: ungapped X-drop, then gapped X-drop DP.
//!
//! Mirrors the blastp pipeline: a two-hit trigger is first extended
//! without gaps along its diagonal (cheap, X-drop terminated); if the
//! ungapped HSP scores above the gapped trigger, a banded affine-gap
//! X-drop extension runs in both directions from the HSP midpoint. Cells
//! visited are counted so the harness can report *effective* GCUPS the
//! way Fig 7 compares BLAST+ to exhaustive SW (heuristics skip most of
//! the matrix — that is exactly their speed story).

use crate::align::scalar::NEG;
use crate::matrices::Scoring;

/// Extension parameters (blastp-flavoured defaults).
#[derive(Clone, Copy, Debug)]
pub struct ExtendParams {
    /// X-drop for the ungapped extension.
    pub x_ungapped: i32,
    /// Raw-score trigger to attempt a gapped extension.
    pub gap_trigger: i32,
    /// X-drop for the gapped extension.
    pub x_gapped: i32,
}

impl Default for ExtendParams {
    fn default() -> Self {
        ExtendParams { x_ungapped: 16, gap_trigger: 38, x_gapped: 38 }
    }
}

/// Result of an ungapped extension.
#[derive(Clone, Copy, Debug)]
pub struct Hsp {
    pub score: i32,
    /// Inclusive query range of the HSP.
    pub q_start: usize,
    pub q_end: usize,
    /// Inclusive subject range.
    pub s_start: usize,
    pub s_end: usize,
    /// DP cells examined.
    pub cells: u64,
}

/// Ungapped X-drop extension of a word hit at (qpos, spos).
pub fn ungapped_extend(
    query: &[u8],
    subject: &[u8],
    qpos: usize,
    spos: usize,
    sc: &Scoring,
    x: i32,
) -> Hsp {
    debug_assert!(qpos < query.len() && spos < subject.len());
    let mut cells = 0u64;

    // right extension (including the anchor cell)
    let mut best = 0i32;
    let mut run = 0i32;
    let mut right = 0usize; // offsets past the anchor of the best end
    {
        let mut k = 0usize;
        while qpos + k < query.len() && spos + k < subject.len() {
            run += sc.score(query[qpos + k], subject[spos + k]);
            cells += 1;
            if run > best {
                best = run;
                right = k + 1;
            }
            if run <= best - x {
                break;
            }
            k += 1;
        }
    }
    // left extension
    let mut left = 0usize;
    {
        let mut run = best;
        let mut peak = best;
        let mut k = 1usize;
        while qpos >= k && spos >= k {
            run += sc.score(query[qpos - k], subject[spos - k]);
            cells += 1;
            if run > peak {
                peak = run;
                left = k;
            }
            if run <= peak - x {
                break;
            }
            k += 1;
        }
        best = peak;
    }
    Hsp {
        score: best,
        q_start: qpos - left,
        q_end: (qpos + right).saturating_sub(1).max(qpos - left),
        s_start: spos - left,
        s_end: (spos + right).saturating_sub(1).max(spos - left),
        cells,
    }
}

/// Gapped X-drop extension from an anchor point, in one direction.
///
/// Antidiagonal-sweep DP over (query suffix × subject suffix) starting at
/// the anchor, keeping only cells within `x` of the running best (the
/// NCBI X-drop band). Returns (best score gained, cells visited).
fn xdrop_directional(q: &[u8], s: &[u8], sc: &Scoring, x: i32, alpha: i32, beta: i32) -> (i32, u64) {
    let n = q.len();
    let m = s.len();
    if n == 0 || m == 0 {
        return (0, 0);
    }
    // row-by-row DP with dynamic live window [lo, hi) per row
    let mut h_prev = vec![NEG; m + 1];
    let mut e_prev = vec![NEG; m + 1]; // E = gap in query direction (vertical)
    h_prev[0] = 0;
    let mut best = 0i32;
    let mut lo = 0usize;
    let mut hi = m + 1;
    let mut cells = 0u64;
    // F border: entering row 0 horizontally
    for j in 1..hi {
        let v = -(beta + (j as i32 - 1) * alpha);
        if v <= -x {
            hi = j;
            break;
        }
        h_prev[j] = v;
    }
    for i in 1..=n {
        let mut h_cur = vec![NEG; m + 1];
        let mut e_cur = vec![NEG; m + 1];
        let mut f = NEG;
        let mut new_lo = usize::MAX;
        let mut new_hi = 0usize;
        let row = sc.row(q[i - 1]);
        let start = lo; // can only shrink from the left
        if start == 0 {
            // vertical border cell
            let v = -(beta + (i as i32 - 1) * alpha);
            h_cur[0] = v;
        }
        for j in start.max(1)..hi.min(m) + 1 {
            if j > m {
                break;
            }
            let e = (e_prev[j] - alpha).max(h_prev[j] - beta);
            f = (f - alpha).max(h_cur[j - 1] - beta);
            let diag = h_prev[j - 1];
            let h = (diag + row[s[j - 1] as usize]).max(e).max(f);
            cells += 1;
            e_cur[j] = e;
            if h >= best - x {
                h_cur[j] = h;
                if h > best {
                    best = h;
                }
                if j < new_lo {
                    new_lo = j;
                }
                if j + 1 > new_hi {
                    new_hi = j + 1;
                }
            }
        }
        if new_lo == usize::MAX {
            break; // entire row dropped: extension done
        }
        lo = new_lo.saturating_sub(1);
        hi = (new_hi + 1).min(m + 1);
        h_prev = h_cur;
        e_prev = e_cur;
    }
    (best.max(0), cells)
}

/// Full gapped extension around an ungapped HSP: extends forward from the
/// HSP end and backward from its start, stitched with the HSP midsection.
///
/// Returns (gapped score, cells). The gapped score is ≥ the HSP score and
/// ≤ the exhaustive SW score (property-tested).
pub fn gapped_extend(
    query: &[u8],
    subject: &[u8],
    hsp: &Hsp,
    sc: &Scoring,
    params: ExtendParams,
) -> (i32, u64) {
    let alpha = sc.gap_extend;
    let beta = sc.beta();
    // anchor at the HSP midpoint
    let mid = (hsp.q_end - hsp.q_start) / 2;
    let (qa, sa) = (hsp.q_start + mid, hsp.s_start + mid);

    // backward: reversed prefixes strictly before the anchor (the anchor
    // pair is added explicitly below)
    let qrev: Vec<u8> = query[..qa].iter().rev().copied().collect();
    let srev: Vec<u8> = subject[..sa].iter().rev().copied().collect();
    let (back, c1) = xdrop_directional(&qrev, &srev, sc, params.x_gapped, alpha, beta);
    // forward: suffixes after the anchor
    let (fwd, c2) = xdrop_directional(
        &query[qa + 1..],
        &subject[sa + 1..],
        sc,
        params.x_gapped,
        alpha,
        beta,
    );
    // anchor residue pair itself
    let anchor = sc.score(query[qa], subject[sa]);
    ((back + anchor + fwd).max(hsp.score).max(0), c1 + c2 + 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::scalar::sw_score;
    use crate::alphabet::encode;
    use crate::db::synth::rand_seq;
    use crate::util::check::{check, prop_assert};
    use crate::util::rng::Rng;

    fn sc() -> Scoring {
        Scoring::blast_default()
    }

    #[test]
    fn ungapped_extends_perfect_match() {
        let s = sc();
        let q = encode(b"AAAWWWWWAAA");
        let d = encode(b"CCCWWWWWCCC");
        let hsp = ungapped_extend(&q, &d, 4, 4, &s, 16);
        // the W-run scores 5*11 = 55; flanks mismatch A/C = 0 each side
        assert_eq!(hsp.score, 55);
        assert!(hsp.q_start >= 3 && hsp.q_end <= 8);
        assert!(hsp.cells > 0);
    }

    #[test]
    fn ungapped_score_at_least_anchor_pair() {
        check("ungapped >= max(0, anchor)", 100, |rng| {
            let q = rand_seq(rng, 5, 60);
            let d = rand_seq(rng, 5, 60);
            let s = sc();
            let qp = rng.range(0, q.len() - 1);
            let sp = rng.range(0, d.len() - 1);
            let hsp = ungapped_extend(&q, &d, qp, sp, &s, 16);
            prop_assert(
                hsp.score >= 0 && hsp.score >= s.score(q[qp], d[sp]).min(0),
                format!("score {}", hsp.score),
            )
        });
    }

    #[test]
    fn gapped_bounded_by_full_sw() {
        check("hsp <= gapped <= sw", 80, |rng| {
            let q = rand_seq(rng, 6, 50);
            let d = rand_seq(rng, 6, 50);
            let s = sc();
            let qp = rng.range(0, q.len() - 1);
            let sp = rng.range(0, d.len() - 1);
            let hsp = ungapped_extend(&q, &d, qp, sp, &s, 16);
            let (g, cells) = gapped_extend(&q, &d, &hsp, &s, ExtendParams::default());
            let full = sw_score(&q, &d, &s);
            prop_assert(
                g <= full,
                format!("gapped {g} exceeds SW {full} (hsp {})", hsp.score),
            )?;
            prop_assert(g >= hsp.score.min(full), format!("gapped {g} < hsp {}", hsp.score))?;
            prop_assert(cells > 0, "no cells")
        });
    }

    #[test]
    fn gapped_recovers_gapped_homology() {
        // query == subject with one 2-residue insertion in the subject:
        // the gapped extension must bridge it, the ungapped one cannot
        let s = sc();
        let mut rng = Rng::new(41);
        let q = rand_seq(&mut rng, 40, 40);
        let mut d = q.clone();
        d.insert(20, 3);
        d.insert(20, 5);
        let hsp = ungapped_extend(&q, &d, 5, 5, &s, 16);
        let (g, _) = gapped_extend(&q, &d, &hsp, &s, ExtendParams::default());
        let full = sw_score(&q, &d, &s);
        assert!(g > hsp.score, "gapped {g} vs ungapped {}", hsp.score);
        // X-drop with default X recovers the optimum on this easy case
        assert_eq!(g, full);
    }

    #[test]
    fn xdrop_cells_bounded_by_full_matrix() {
        let s = sc();
        let mut rng = Rng::new(42);
        let q = rand_seq(&mut rng, 80, 80);
        let d = rand_seq(&mut rng, 80, 80);
        let (_, cells) = xdrop_directional(&q, &d, &s, 20, s.gap_extend, s.beta());
        assert!(cells <= (q.len() * d.len()) as u64);
        // X-drop must prune most of a random (non-homologous) matrix
        assert!(
            cells < (q.len() * d.len()) as u64 / 2,
            "cells {cells} of {}",
            q.len() * d.len()
        );
    }

    #[test]
    fn empty_directional_inputs() {
        let s = sc();
        assert_eq!(xdrop_directional(&[], &[1, 2], &s, 10, 1, 11), (0, 0));
        assert_eq!(xdrop_directional(&[1, 2], &[], &s, 10, 1, 11), (0, 0));
    }
}

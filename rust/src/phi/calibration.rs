//! Calibration constants and per-variant cost models for the simulated
//! devices (DESIGN.md §2, §7).
//!
//! The container has one CPU core and no Xeon Phi, so *reported* GCUPS
//! for the figure harnesses comes from a discrete-event simulation whose
//! per-thread throughput model is calibrated here. Anchors:
//!
//! * Xeon Phi 5110P (paper §IV.A): 60 cores × 4 threads at 1.05 GHz; the
//!   paper's single-coprocessor InterSP plateau ≈ 58.8 GCUPS over 240
//!   device threads.
//! * Xeon E5-2670 (paper's host): SWIPE reaches 80.1 GCUPS avg on 8
//!   cores at 2.6 GHz → ≈ 10 GCUPS/core.
//! * GeForce GTX Titan (Fig 8 comparator): CUDASW++ 3.0 GPU-only avg
//!   108.9, max 115.4 GCUPS — used as an external comparator *curve*,
//!   not a system we model internally.
//!
//! The per-variant models keep the *mechanisms*, not just the numbers:
//!
//! * InterSP pays a score-profile rebuild per 8-position window whose
//!   cost amortizes over query length (the Fig 5 SP/QP crossover at
//!   ≈ 375);
//! * InterQP pays a per-cell gather premium but almost no per-column
//!   overhead;
//! * IntraQP pays striped padding (`⌈q/16⌉·16` lane quantization — the
//!   Fig 5 fluctuation) plus a memory-hierarchy penalty once the striped
//!   working set outgrows the 512 KB L2 (the paper's "device memory
//!   accesses are still heavy" observation).
//!
//! `measured_ratio_*` lets harnesses re-derive the InterSP : InterQP :
//! IntraQP ratios from *this container's* native engines instead of the
//! paper anchors, so the variant ordering in our Fig 5 is an emergent
//! measurement (EXPERIMENTS.md reports both).

use crate::align::EngineKind;

/// Cells/second one Phi device thread sustains at infinite query length,
/// per variant. 240 threads × 0.2479e9 ≈ 59.5 GCUPS (InterSP plateau
/// slightly above the paper's observed 58.8 max, which includes offload
/// overheads the simulator charges separately).
pub fn phi_thread_rate(kind: EngineKind) -> f64 {
    match kind {
        EngineKind::InterSP => 59.5e9 / PHI_THREADS as f64,
        EngineKind::InterQP => 54.5e9 / PHI_THREADS as f64,
        // intra plateau before utilization/memory penalties
        EngineKind::IntraQP => 50.0e9 / PHI_THREADS as f64,
        EngineKind::Scalar => 2.0e9 / PHI_THREADS as f64,
    }
}

/// Per-variant "overhead length" C: effective rate at query length q is
/// `rate / (1 + C/q)`. For InterSP, C models the score-profile rebuild
/// amortization; the SP/QP pair is tuned so the crossover falls at
/// q ≈ 366 (paper: ≥ 375 favours SP).
pub fn phi_overhead_len(kind: EngineKind) -> f64 {
    match kind {
        EngineKind::InterSP => 50.0,
        EngineKind::InterQP => 15.0,
        EngineKind::IntraQP => 25.0,
        EngineKind::Scalar => 5.0,
    }
}

/// Device-thread counts of the paper's coprocessor.
pub const PHI_CORES: usize = 60;
pub const PHI_THREADS_PER_CORE: usize = 4;
pub const PHI_THREADS: usize = PHI_CORES * PHI_THREADS_PER_CORE;
pub const PHI_CLOCK_GHZ: f64 = 1.05;

/// Offload model (LEO): fixed invocation latency per offload region plus
/// PCIe gen2 x16 effective bandwidth for chunk transfer.
pub const OFFLOAD_LATENCY_S: f64 = 250e-6;
pub const OFFLOAD_BANDWIDTH_BPS: f64 = 6.0e9;
/// One-time per-(query, device) setup: query/profile upload + region init.
pub const OFFLOAD_SETUP_S: f64 = 3.0e-3;

/// Host CPU (2 × E5-2670) per-core rates for the Fig 7 baselines.
/// SWIPE ≈ 10 GCUPS/core (paper: 80.1 avg / 8 cores); its inter-sequence
/// kernel has tiny per-query overhead.
pub const SWIPE_CORE_RATE: f64 = 10.3e9;
pub const SWIPE_OVERHEAD_LEN: f64 = 8.0;
/// Dual-socket scaling efficiency at 16 cores (paper: 149.1/80.1 = 1.86×
/// for 2×, i.e. ~93%).
pub const HOST_16C_EFFICIENCY: f64 = 0.93;

/// BLAST visited-cell processing rate per core (scalar-ish DP + seeding).
pub const BLAST_VISIT_RATE: f64 = 1.6e9;
/// Per-subject seeding scan cost (s) per subject residue per core.
pub const BLAST_SCAN_COST_PER_RESIDUE: f64 = 1.0 / 2.4e9;
/// Per word-hit processing cost (diagonal-array update, two-hit check).
/// Calibrated so BLAST+ on 8 cores lands at the paper's measured
/// ~175 GCUPS average over the query panel given our measured seeding
/// statistics (the *variance* across queries stays a measurement).
pub const BLAST_HIT_COST: f64 = 20e-9;

/// CUDASW++ 3.0 on a GTX Titan (Fig 8 comparator curve): plateau and
/// overhead length fitted to the paper's avg 108.9 / max 115.4.
pub fn titan_gcups(qlen: usize) -> f64 {
    116.0e9 * qlen as f64 / (qlen as f64 + 35.0) / 1e9
}

/// Striped-lane utilization of a query under 16-lane striping — the
/// IntraQP sawtooth (real striped engines compute ⌈q/16⌉·16 lanes).
pub fn striped_utilization(qlen: usize) -> f64 {
    if qlen == 0 {
        return 1.0;
    }
    let lanes = 16.0;
    let padded = (qlen as f64 / lanes).ceil() * lanes;
    qlen as f64 / padded
}

/// IntraQP memory-hierarchy derating: striped H/E/F working set is
/// ~ 3 vectors × ⌈q/16⌉ × 64 B; past the 512 KB per-core L2 the paper
/// observed heavy memory traffic. Smooth penalty with knee ≈ q = 2700.
pub fn intra_memory_factor(qlen: usize) -> f64 {
    let knee = 2700.0;
    1.0 / (1.0 + (qlen as f64 / knee).powf(1.2) * 0.35)
}

/// Throughput multiple of the narrow (i16) tier over the i32 kernels for
/// the inter-sequence engines: 32 saturating 16-bit lanes fill the same
/// 512-bit vector that held 16 × i32, so the ideal is 2.0×; overflow
/// bookkeeping and the unchanged per-column scalar overheads derate it
/// (SSW and the lazy-F striped line report 1.6–1.8× in practice).
pub const I16_RATE_FACTOR: f64 = 1.7;

/// Narrow-tier speedup per variant: only the inter-sequence engines have
/// a 32-lane tier; striped/scalar stay at 1.0.
pub fn i16_rate_factor(kind: EngineKind) -> f64 {
    match kind {
        EngineKind::InterSP | EngineKind::InterQP => I16_RATE_FACTOR,
        EngineKind::IntraQP | EngineKind::Scalar => 1.0,
    }
}

/// Effective per-thread rate (cells/s) for a variant at a query length —
/// the quantity the discrete-event simulator charges per padded cell.
pub fn effective_thread_rate(kind: EngineKind, qlen: usize) -> f64 {
    let base = phi_thread_rate(kind) / (1.0 + phi_overhead_len(kind) / qlen.max(1) as f64);
    match kind {
        EngineKind::IntraQP => base * striped_utilization(qlen) * intra_memory_factor(qlen),
        _ => base,
    }
}

/// Measure this container's native-engine per-cell ratios (InterSP = 1.0
/// baseline) on a small workload — used by harnesses to report emergent
/// variant ordering alongside the anchored model.
pub fn measured_variant_ratios() -> [(EngineKind, f64); 3] {
    use crate::align::{search_index, NativeAligner, QueryContext};
    use crate::db::index::Index;
    use crate::db::synth::{generate, generate_query, SynthSpec};
    use std::time::Instant;

    let idx = Index::build(generate(&SynthSpec::tiny(240, 1234)));
    let sc = crate::matrices::Scoring::swaphi_default();
    let q = generate_query(256, 99);
    let ctx = QueryContext::build("calib", q, &sc);
    let mut out = [(EngineKind::InterSP, 1.0), (EngineKind::InterQP, 1.0), (EngineKind::IntraQP, 1.0)];
    let mut base = 0.0;
    for (slot, kind) in EngineKind::PAPER_VARIANTS.iter().enumerate() {
        let mut eng = NativeAligner::new(*kind);
        // warmup
        let _ = search_index(&mut eng, &ctx, &idx, &sc);
        let t = Instant::now();
        let _ = search_index(&mut eng, &ctx, &idx, &sc);
        let dt = t.elapsed().as_secs_f64();
        let rate = 1.0 / dt;
        if slot == 0 {
            base = rate;
        }
        out[slot] = (*kind, rate / base);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sp_beats_qp_only_above_crossover() {
        let sp_short = effective_thread_rate(EngineKind::InterSP, 144);
        let qp_short = effective_thread_rate(EngineKind::InterQP, 144);
        assert!(qp_short > sp_short, "QP should win short queries");
        let sp_long = effective_thread_rate(EngineKind::InterSP, 1000);
        let qp_long = effective_thread_rate(EngineKind::InterQP, 1000);
        assert!(sp_long > qp_long, "SP should win long queries");
        // crossover in the paper's observed band (between 222 and 464)
        let mut cross = 0;
        for q in 144..2000 {
            let sp = effective_thread_rate(EngineKind::InterSP, q);
            let qp = effective_thread_rate(EngineKind::InterQP, q);
            if sp >= qp {
                cross = q;
                break;
            }
        }
        assert!((222..=464).contains(&cross), "crossover at {cross}");
    }

    #[test]
    fn intra_is_slowest_variant_and_fluctuates() {
        for q in [144usize, 464, 1000, 5478] {
            let intra = effective_thread_rate(EngineKind::IntraQP, q);
            let sp = effective_thread_rate(EngineKind::InterSP, q);
            assert!(intra < sp, "q={q}");
        }
        // sawtooth: utilization dips just past multiples of 16
        assert!(striped_utilization(64) > striped_utilization(65));
        assert!((striped_utilization(64) - 1.0).abs() < 1e-12);
        assert!((striped_utilization(65) - 65.0 / 80.0).abs() < 1e-12);
    }

    #[test]
    fn intra_declines_for_very_long_queries() {
        let peak = effective_thread_rate(EngineKind::IntraQP, 464);
        let long = effective_thread_rate(EngineKind::IntraQP, 5472);
        assert!(long < peak, "L2 derating should bite at 5.5k");
    }

    #[test]
    fn single_device_plateau_near_paper() {
        // 240 threads at q=5478 should land in the paper's ballpark
        let g = effective_thread_rate(EngineKind::InterSP, 5478) * PHI_THREADS as f64 / 1e9;
        assert!((55.0..62.0).contains(&g), "plateau {g}");
    }

    #[test]
    fn titan_curve_matches_anchors() {
        assert!((titan_gcups(5478) - 115.3).abs() < 1.5);
        // average over the paper's panel lands near 108.9
        let lens = crate::db::synth::PAPER_QUERY_LENS;
        let avg: f64 = lens.iter().map(|&q| titan_gcups(q)).sum::<f64>() / lens.len() as f64;
        assert!((104.0..113.0).contains(&avg), "avg {avg}");
    }

    #[test]
    fn rates_positive_and_finite() {
        for kind in EngineKind::PAPER_VARIANTS {
            for q in [1usize, 144, 5478, 100_000] {
                let r = effective_thread_rate(kind, q);
                assert!(r.is_finite() && r > 0.0, "{kind:?} q={q}");
            }
        }
    }
}

//! Xeon Phi coprocessor substitution layer: device model, offload cost
//! model, OpenMP-style schedulers and the discrete-event simulator that
//! turns real chunk workloads into paper-comparable GCUPS numbers
//! (DESIGN.md §2 — the hardware substitution).

pub mod calibration;
pub mod offload;
pub mod sched;
pub mod sim;

//! Offload (LEO) cost model — paper §II.B.
//!
//! The offload model "sends input data and code to the coprocessor at
//! startup time of an offload region, and then transfers back the output
//! data"; each chunk offload pays a fixed invocation latency plus PCIe
//! transfer time, and each (query, device) pair pays a one-time setup.
//! Fig 8's droop on the small database is exactly these costs failing to
//! amortize — the simulator reproduces it from the same mechanism.

use super::calibration;

/// Offload cost parameters (seconds / bytes-per-second).
#[derive(Clone, Copy, Debug)]
pub struct OffloadModel {
    /// Fixed latency per offload region invocation.
    pub latency_s: f64,
    /// Effective host↔device bandwidth.
    pub bandwidth_bps: f64,
    /// One-time per-(query, device) setup (query profile upload, region
    /// initialization).
    pub setup_s: f64,
}

impl Default for OffloadModel {
    fn default() -> Self {
        OffloadModel {
            latency_s: calibration::OFFLOAD_LATENCY_S,
            bandwidth_bps: calibration::OFFLOAD_BANDWIDTH_BPS,
            setup_s: calibration::OFFLOAD_SETUP_S,
        }
    }
}

impl OffloadModel {
    /// A hypothetical zero-cost offload (native-model ablation).
    pub fn free() -> Self {
        OffloadModel { latency_s: 0.0, bandwidth_bps: f64::INFINITY, setup_s: 0.0 }
    }

    /// Cost of offloading one chunk of `bytes` (input transfer; the
    /// returned scores are negligible next to the input).
    pub fn chunk_cost(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_cost_components() {
        let m = OffloadModel { latency_s: 1e-3, bandwidth_bps: 1e9, setup_s: 0.0 };
        assert!((m.chunk_cost(1_000_000) - (1e-3 + 1e-3)).abs() < 1e-12);
        assert!((m.chunk_cost(0) - 1e-3).abs() < 1e-15);
    }

    #[test]
    fn free_model_costs_nothing() {
        let m = OffloadModel::free();
        assert_eq!(m.chunk_cost(u64::MAX), 0.0);
        assert_eq!(m.setup_s, 0.0);
    }

    #[test]
    fn default_matches_calibration() {
        let m = OffloadModel::default();
        assert_eq!(m.latency_s, calibration::OFFLOAD_LATENCY_S);
        // a 4 MiB chunk should cost well under 2 ms on PCIe gen2 x16
        assert!(m.chunk_cost(4 << 20) < 2e-3);
    }
}

//! Discrete-event simulation of a multi-coprocessor SWAPHI search.
//!
//! Two scheduling levels, exactly the paper's decomposition (Fig 2):
//!
//! 1. **host level** — one host thread per coprocessor pulls chunks
//!    dynamically from the shared pool of workloads; each chunk pays the
//!    offload cost, then its compute latency;
//! 2. **device level** — within a chunk, the alignment loop (one
//!    sequence profile / subject per iteration) is spread over the 240
//!    device threads under an OpenMP policy ([`sched::simulate_schedule`]).
//!
//! The simulator charges *padded* cells at the calibrated per-thread rate
//! (padding waste and load imbalance are therefore emergent, not
//! assumed), and reports GCUPS over *real* cells like the paper does.
//! Fig 5/6/8's shapes — query-length growth, near-linear device scaling,
//! small-database droop — all emerge from these two mechanisms plus the
//! offload model.

use super::calibration::{self, PHI_THREADS};
use super::offload::OffloadModel;
use super::sched::{simulate_schedule, Policy};
use crate::align::{EngineKind, Precision};
use crate::coordinator::devices::{pick_steal_victim, DeviceTimeline};
use crate::db::chunk::Chunk;
use crate::db::index::Index;
use crate::db::profile::LANES;

/// Simulated coprocessor fleet configuration.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub devices: usize,
    pub threads_per_device: usize,
    pub policy: Policy,
    pub offload: OffloadModel,
    /// Virtual workload replication: the synthetic database is a *sample*
    /// of the paper-scale corpus (TrEMBL is 13.2 G residues; generating it
    /// for real is pointless), so each chunk's item list is tiled this
    /// many times — chunk sizes, item counts per device thread, transfer
    /// bytes and cell totals all scale to realistic magnitudes while the
    /// length *distribution* stays the measured one. 1 = no scaling.
    pub replication: usize,
    /// Score-lane tier being simulated. `I16`/`Auto` charges padded cells
    /// at the narrow-tier rate (× [`calibration::i16_rate_factor`]) plus
    /// a second full-precision pass over `rescore_fraction` of the work.
    /// Default `I32` keeps the paper-anchored figures unchanged.
    pub precision: Precision,
    /// Fraction of narrow-tier alignments that overflow and rescore
    /// (coordinator feeds back the measured value).
    pub rescore_fraction: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            devices: 1,
            threads_per_device: PHI_THREADS,
            policy: Policy::Guided,
            offload: OffloadModel::default(),
            replication: 1,
            precision: Precision::I32,
            rescore_fraction: 0.0,
        }
    }
}

/// Simulation outcome for one query search.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// End-to-end simulated wall time (s).
    pub makespan: f64,
    /// Real (unpadded) cells of the workload.
    pub real_cells: u128,
    /// Padded cells actually charged.
    pub padded_cells: u128,
    /// Total offload time across devices (s).
    pub offload_time: f64,
    /// Total compute busy time across devices (s).
    pub compute_time: f64,
    /// Per-device completion times (s).
    pub device_done: Vec<f64>,
    /// Chunks processed per device.
    pub chunks_per_device: Vec<usize>,
    /// Chunks each device stole from another device's queue (all zero
    /// for the pooled schedulers; populated by
    /// [`simulate_sharded_search`]).
    pub stolen_chunks: Vec<usize>,
    /// Compute-busy seconds per device (offload and setup excluded) —
    /// with [`SimReport::device_padded_cells`], the deterministic
    /// observation stream the online-calibration estimator consumes in
    /// [`simulate_calibrated_search`].
    pub device_compute_s: Vec<f64>,
    /// Padded DP cells each device computed.
    pub device_padded_cells: Vec<u128>,
}

impl SimReport {
    /// Paper-style GCUPS: real cells / makespan.
    pub fn gcups(&self) -> f64 {
        crate::util::gcups(self.real_cells, self.makespan)
    }

    /// Fraction of makespan×devices spent on offload overhead.
    pub fn offload_fraction(&self) -> f64 {
        let cap = self.makespan * self.device_done.len() as f64;
        if cap <= 0.0 {
            0.0
        } else {
            self.offload_time / cap
        }
    }

    /// Per-device compute/steal/idle timeline in the exact shape the
    /// real fleet reports ([`DeviceTimeline`], microseconds): busy time
    /// is `device_compute_s` split by stolen-chunk share, idle is the
    /// barrier tail `makespan - device_done[d]` plus any non-compute
    /// wait inside the device's own clock. The sim models the fleet the
    /// paper scales across; keeping the two report shapes identical is
    /// what lets the straggler analysis run against either.
    pub fn device_timeline(&self) -> Vec<DeviceTimeline> {
        let us = |s: f64| (s.max(0.0) * 1e6) as u64;
        (0..self.device_done.len())
            .map(|d| {
                let busy = self.device_compute_s.get(d).copied().unwrap_or(0.0);
                let chunks = self.chunks_per_device.get(d).copied().unwrap_or(0);
                let stolen = self.stolen_chunks.get(d).copied().unwrap_or(0).min(chunks);
                let steal_share = if chunks == 0 {
                    0.0
                } else {
                    stolen as f64 / chunks as f64
                };
                // same definition as WorkQueues::finish_timed: idle is
                // batch wall (makespan) minus compute-busy time — both
                // the offload/setup overhead and the barrier tail count
                // as not-computing
                DeviceTimeline {
                    device: d,
                    compute_us: us(busy * (1.0 - steal_share)),
                    steal_us: us(busy * steal_share),
                    idle_us: us(self.makespan - busy),
                }
            })
            .collect()
    }
}

/// Per-item (loop-iteration) costs of one chunk, per the engine variant.
///
/// Inter-sequence: one iteration = one 16-lane sequence profile.
/// Intra-sequence: one iteration = one subject sequence.
fn chunk_item_costs(index: &Index, chunk: &Chunk, kind: EngineKind, qlen: usize, cfg: &SimConfig) -> Vec<f64> {
    let rate = calibration::effective_thread_rate(kind, qlen);
    // Narrow (i16) tier: the same cells at i16_rate_factor × the i32
    // rate, plus a second full-precision pass over the overflow fraction.
    // time = cells/rate16 + f·cells/rate32 = (cells/rate32)·(1/factor + f)
    let tier_scale = match cfg.precision {
        Precision::I32 => 1.0,
        Precision::I16 | Precision::Auto => {
            1.0 / calibration::i16_rate_factor(kind) + cfg.rescore_fraction.clamp(0.0, 1.0)
        }
    };
    let profiles = &index.profiles[chunk.profile_start..chunk.profile_end];
    let one: Vec<f64> = match kind {
        EngineKind::IntraQP | EngineKind::Scalar => profiles
            .iter()
            .flat_map(|p| {
                p.lens[..p.used]
                    .iter()
                    .map(move |&l| tier_scale * (l as f64 * qlen as f64) / rate)
            })
            .collect(),
        _ => profiles
            .iter()
            .map(|p| tier_scale * (p.padded_len * LANES) as f64 * qlen as f64 / rate)
            .collect(),
    };
    let replication = cfg.replication.max(1);
    if replication <= 1 {
        return one;
    }
    let mut out = Vec::with_capacity(one.len() * replication);
    for _ in 0..replication {
        out.extend_from_slice(&one);
    }
    out
}

/// One worker of a heterogeneous simulated fleet — the general form of
/// the paper's §V hybrid model (Phi-class and SWIPE-class workers with
/// very different throughputs cooperating on one database pass).
#[derive(Clone, Copy, Debug)]
pub enum Worker {
    /// Phi-class coprocessor: pays the offload model; chunk latency is
    /// the 240-thread schedule makespan divided by `rate` (1.0 = the
    /// calibrated 5110P).
    Phi { rate: f64 },
    /// Host-CPU (SWIPE-class) worker: no offload cost; `rate` is an
    /// absolute aggregate throughput in cells/s.
    Host { rate: f64 },
}

/// Shared-pool scheduling over an arbitrary worker fleet: the
/// earliest-free worker takes the next chunk (paper: "obtains a chunk of
/// database sequences from its pool of workloads"). [`simulate_search`]
/// is the all-Phi uniform special case and [`simulate_hybrid_search`]
/// the 2-rate Phi+host one.
pub fn simulate_pooled(
    index: &Index,
    chunks: &[Chunk],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    workers: &[Worker],
) -> SimReport {
    assert!(!workers.is_empty(), "need at least one worker");
    let rep = cfg.replication.max(1) as u128;
    let mut clock: Vec<f64> = workers
        .iter()
        .map(|w| match w {
            Worker::Phi { .. } => cfg.offload.setup_s,
            Worker::Host { .. } => 0.0,
        })
        .collect();
    let mut chunks_per = vec![0usize; workers.len()];
    let n_phi = workers.iter().filter(|w| matches!(w, Worker::Phi { .. })).count();
    let mut offload_time = cfg.offload.setup_s * n_phi as f64;
    let mut compute_time = 0.0;
    let mut padded_cells: u128 = 0;
    let mut device_compute_s = vec![0.0f64; workers.len()];
    let mut device_padded_cells = vec![0u128; workers.len()];

    for chunk in chunks {
        let (w, _) = clock
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let cells = chunk.padded_cells(qlen) * rep;
        match workers[w] {
            Worker::Phi { rate } => {
                let off = cfg.offload.chunk_cost(chunk.transfer_bytes * rep as u64);
                // device level: OpenMP loop schedule across device threads
                let costs = chunk_item_costs(index, chunk, kind, qlen, &cfg);
                let outcome = simulate_schedule(&costs, cfg.threads_per_device, cfg.policy);
                clock[w] += off + outcome.makespan / rate;
                offload_time += off;
                compute_time += outcome.makespan / rate;
                device_compute_s[w] += outcome.makespan / rate;
            }
            Worker::Host { rate } => {
                let dt = cells as f64 / rate;
                clock[w] += dt;
                compute_time += dt;
                device_compute_s[w] += dt;
            }
        }
        chunks_per[w] += 1;
        padded_cells += cells;
        device_padded_cells[w] += cells;
    }

    let makespan = clock.iter().cloned().fold(0.0, f64::max);
    SimReport {
        makespan,
        real_cells: chunks.iter().map(|c| c.real_cells(qlen) * rep).sum(),
        padded_cells,
        offload_time,
        compute_time,
        stolen_chunks: vec![0; clock.len()],
        device_done: clock,
        chunks_per_device: chunks_per,
        device_compute_s,
        device_padded_cells,
    }
}

/// Simulate one query search over pre-planned chunks (a uniform fleet of
/// `cfg.devices` full-rate coprocessors sharing the chunk pool).
pub fn simulate_search(
    index: &Index,
    chunks: &[Chunk],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
) -> SimReport {
    assert!(cfg.devices >= 1);
    let workers = vec![Worker::Phi { rate: 1.0 }; cfg.devices];
    simulate_pooled(index, chunks, kind, qlen, cfg, &workers)
}

/// Simulate one query search under the **sharded multi-device layer**:
/// each device owns a static chunk shard (`shards[d]` = ascending chunk
/// indices, e.g. from [`crate::db::chunk::partition_chunks`]) and drains
/// it front-first; when its queue is empty and `steal` is set, it steals
/// the *back* of the deepest remaining queue — exactly the discipline the
/// real `DeviceSet` work queues implement, so the simulated makespan
/// tracks the execution layer shipping in the coordinator.
pub fn simulate_sharded_search(
    index: &Index,
    chunks: &[Chunk],
    shards: &[Vec<usize>],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    steal: bool,
) -> SimReport {
    let rates = vec![1.0; shards.len()];
    simulate_sharded_rates(index, chunks, shards, kind, qlen, cfg, steal, &rates)
}

/// Rate-aware sharded simulation: device `d` runs at `rates[d]` × the
/// calibrated coprocessor speed (compute scales; PCIe offload does not),
/// and an idle device steals from the victim with the largest *estimated
/// remaining time* — queue depth ÷ rate, the same policy as the real
/// `DeviceSet` — so fast devices strip-mine slow ones first. A uniform
/// rate vector is bit-identical to [`simulate_sharded_search`].
#[allow(clippy::too_many_arguments)]
pub fn simulate_sharded_rates(
    index: &Index,
    chunks: &[Chunk],
    shards: &[Vec<usize>],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    steal: bool,
    rates: &[f64],
) -> SimReport {
    simulate_sharded_mismodeled(index, chunks, shards, kind, qlen, cfg, steal, rates, rates)
}

/// The mis-modeled general case of [`simulate_sharded_rates`]: devices
/// *run* at `true_rates` but the steal policy *believes* `policy_rates`
/// (victim selection and the profitability guard use beliefs — exactly
/// what the real execution layer does when its configured rates are
/// wrong). `policy_rates == true_rates` reproduces
/// [`simulate_sharded_rates`] bit-for-bit; the calibration loop
/// ([`simulate_calibrated_search`]) closes the gap between the two
/// vectors online.
#[allow(clippy::too_many_arguments)]
pub fn simulate_sharded_mismodeled(
    index: &Index,
    chunks: &[Chunk],
    shards: &[Vec<usize>],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    steal: bool,
    true_rates: &[f64],
    policy_rates: &[f64],
) -> SimReport {
    assert!(cfg.devices >= 1);
    assert_eq!(shards.len(), cfg.devices, "one shard per device");
    assert_eq!(true_rates.len(), cfg.devices, "one rate per device");
    assert_eq!(policy_rates.len(), cfg.devices, "one believed rate per device");
    for rates in [true_rates, policy_rates] {
        assert!(
            rates.iter().all(|r| r.is_finite() && *r > 0.0),
            "device rates must be finite and positive: {rates:?}"
        );
    }
    let rep = cfg.replication.max(1) as u128;
    let mut queues: Vec<std::collections::VecDeque<usize>> =
        shards.iter().map(|s| s.iter().copied().collect()).collect();
    let mut device_clock = vec![cfg.offload.setup_s; cfg.devices];
    let mut done = vec![false; cfg.devices];
    let mut chunks_per_device = vec![0usize; cfg.devices];
    let mut stolen_chunks = vec![0usize; cfg.devices];
    let mut offload_time = cfg.offload.setup_s * cfg.devices as f64;
    let mut compute_time = 0.0;
    let mut padded_cells: u128 = 0;
    let mut device_compute_s = vec![0.0f64; cfg.devices];
    let mut device_padded_cells = vec![0u128; cfg.devices];

    loop {
        // earliest-free device that hasn't retired (ties to lowest index)
        let Some(dev) = (0..cfg.devices)
            .filter(|&d| !done[d])
            .min_by(|&a, &b| device_clock[a].partial_cmp(&device_clock[b]).unwrap())
        else {
            break;
        };
        // own queue front, else the shared steal policy — the SAME
        // implementation the real `DeviceSet` work queues run (victim
        // by estimated remaining time, profitability-guarded), so the
        // simulated fleet can never drift from the execution layer.
        // The policy consults the *believed* rates; time advances by the
        // *true* ones.
        let mut item = queues[dev].pop_front();
        if item.is_none() && steal {
            if let Some(v) =
                pick_steal_victim(queues.iter().map(|q| q.len()), policy_rates, dev)
            {
                item = queues[v].pop_back();
                if item.is_some() {
                    stolen_chunks[dev] += 1;
                }
            }
        }
        let Some(c) = item else {
            done[dev] = true;
            continue;
        };
        let chunk = &chunks[c];
        let off = cfg.offload.chunk_cost(chunk.transfer_bytes * rep as u64);
        let costs = chunk_item_costs(index, chunk, kind, qlen, &cfg);
        let outcome = simulate_schedule(&costs, cfg.threads_per_device, cfg.policy);
        device_clock[dev] += off + outcome.makespan / true_rates[dev];
        chunks_per_device[dev] += 1;
        offload_time += off;
        compute_time += outcome.makespan / true_rates[dev];
        device_compute_s[dev] += outcome.makespan / true_rates[dev];
        padded_cells += chunk.padded_cells(qlen) * rep;
        device_padded_cells[dev] += chunk.padded_cells(qlen) * rep;
    }

    let makespan = device_clock.iter().cloned().fold(0.0, f64::max);
    SimReport {
        makespan,
        real_cells: shards
            .iter()
            .flatten()
            .map(|&c| chunks[c].real_cells(qlen) * rep)
            .sum(),
        padded_cells,
        offload_time,
        compute_time,
        stolen_chunks,
        device_done: device_clock,
        chunks_per_device,
        device_compute_s,
        device_padded_cells,
    }
}

/// A drifting-rate calibration scenario for
/// [`simulate_calibrated_search`]: the fleet is *configured* with one
/// rate vector while the devices *truly* run at others, possibly
/// changing mid-run — the deterministic test bench for the online
/// calibration loop ([`crate::tune`]).
#[derive(Clone, Debug)]
pub struct CalibratedScenario {
    /// The operator-supplied rate vector the run starts from.
    pub configured: Vec<f64>,
    /// `(from_batch, true_rates)` segments, ascending; the first entry
    /// must start at batch 0. Each segment's vector applies from its
    /// batch index until the next segment.
    pub true_rates: Vec<(usize, Vec<f64>)>,
    /// Batches to simulate.
    pub batches: usize,
    /// The calibration knobs under test.
    pub tune: crate::tune::TuneConfig,
}

/// One batch of a calibrated run.
#[derive(Clone, Debug)]
pub struct CalibratedBatch {
    /// The batch's simulated makespan (setup + offload + compute).
    pub makespan: f64,
    /// Rates the fleet *believed* (sharded and stole by) this batch.
    pub believed: Vec<f64>,
    /// Rates the devices truly ran at.
    pub true_rates: Vec<f64>,
    /// The perfectly-divisible bound for this batch under the true
    /// rates: `setup + (single-device work) / Σtrue` — the same ideal
    /// the `multi_device_scaling` bench and CI gate use.
    pub ideal: f64,
    /// Did the barrier after this batch adopt new rates (re-shard)?
    pub resharded_after: bool,
}

/// Outcome of [`simulate_calibrated_search`].
#[derive(Clone, Debug)]
pub struct CalibratedSimReport {
    pub batches: Vec<CalibratedBatch>,
    /// The tuner's final calibrated estimate (normalized to the
    /// configured sum).
    pub calibrated: Vec<f64>,
    /// Re-shards (rate adoptions) over the whole run.
    pub resharded_total: u64,
    /// Σ batch makespans.
    pub total_makespan: f64,
    /// Real cells per batch (every batch runs the full chunk plan).
    pub batch_real_cells: u128,
}

impl CalibratedSimReport {
    /// GCUPS over the whole run (all batches, warmup included).
    pub fn gcups(&self) -> f64 {
        crate::util::gcups(
            self.batch_real_cells * self.batches.len() as u128,
            self.total_makespan,
        )
    }
}

/// Deterministic closed-loop calibration simulation: each batch shards
/// by the *believed* rates, executes under the *true* rates
/// ([`simulate_sharded_mismodeled`]), feeds the per-device compute
/// clocks into a [`Tuner`](crate::tune::Tuner) exactly as the real
/// execution layer's timing hooks do, and re-shards at the barrier when
/// the tuner says so. True rates may change mid-run — the tuner must
/// detect the drift and converge again. This is the mechanism the
/// `miscalibrated` bench scenario and the CI gates run.
pub fn simulate_calibrated_search(
    index: &Index,
    chunks: &[Chunk],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    scenario: &CalibratedScenario,
) -> CalibratedSimReport {
    use crate::db::chunk::partition_chunks_weighted;
    let n = scenario.configured.len();
    assert!(n >= 1, "need at least one device");
    assert!(
        scenario.true_rates.first().is_some_and(|(b, _)| *b == 0),
        "true_rates must start at batch 0"
    );
    for (from, rates) in &scenario.true_rates {
        assert_eq!(rates.len(), n, "segment at batch {from}: one true rate per device");
    }
    let cfg = SimConfig { devices: n, ..cfg };
    let tuner = crate::tune::Tuner::new(&scenario.configured, scenario.tune.clone());

    // the per-batch ideal is rate-independent work over Σrate: measure
    // the single-device batch once (setup + Σ(offload + compute))
    let single = simulate_search(
        index,
        chunks,
        kind,
        qlen,
        SimConfig { devices: 1, ..cfg },
    );
    let setup = cfg.offload.setup_s;

    let mut believed = scenario.configured.clone();
    let mut batches = Vec::with_capacity(scenario.batches);
    let mut total_makespan = 0.0;
    for b in 0..scenario.batches {
        let truth = &scenario
            .true_rates
            .iter()
            .rev()
            .find(|(from, _)| *from <= b)
            .expect("segment coverage checked above")
            .1;
        let believed_this_batch = believed.clone();
        let shards = partition_chunks_weighted(chunks, &believed_this_batch);
        let r = simulate_sharded_mismodeled(
            index, chunks, &shards, kind, qlen, cfg, true, truth, &believed_this_batch,
        );
        // the deterministic clocks are the timing hooks: one observation
        // per device per batch (cells computed, compute-busy seconds)
        for d in 0..n {
            tuner.observe(d, r.device_padded_cells[d] as f64, r.device_compute_s[d]);
        }
        let resharded_after = match tuner.end_batch() {
            Some(rates) => {
                believed = rates;
                true
            }
            None => false,
        };
        total_makespan += r.makespan;
        batches.push(CalibratedBatch {
            makespan: r.makespan,
            believed: believed_this_batch,
            true_rates: truth.clone(),
            ideal: setup + (single.makespan - setup) / truth.iter().sum::<f64>(),
            resharded_after,
        });
    }
    CalibratedSimReport {
        batches,
        calibrated: tuner.calibrated(),
        resharded_total: tuner.adoptions(),
        total_makespan,
        batch_real_cells: single.real_cells,
    }
}

/// Hybrid CPU + coprocessor execution — the paper's §V future-work
/// extension ("concurrent execution of alignments on both CPUs and
/// coprocessors by means of a hybrid parallelism model", as CUDASW++ 3.0
/// does on GPUs): host CPU cores join the chunk pool as one extra
/// worker with SWIPE-class throughput and zero offload cost. A 2-rate
/// special case of the general [`simulate_pooled`] worker-fleet model.
pub fn simulate_hybrid_search(
    index: &Index,
    chunks: &[Chunk],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    host_cores: usize,
) -> SimReport {
    assert!(cfg.devices >= 1);
    // workers: [0..devices) = coprocessors, [devices] = host CPU
    let mut workers = vec![Worker::Phi { rate: 1.0 }; cfg.devices];
    if host_cores > 0 {
        let host_rate = calibration::SWIPE_CORE_RATE
            * host_cores as f64
            * if host_cores > 8 { calibration::HOST_16C_EFFICIENCY } else { 1.0 }
            / (1.0 + calibration::SWIPE_OVERHEAD_LEN / qlen.max(1) as f64);
        workers.push(Worker::Host { rate: host_rate });
    }
    simulate_pooled(index, chunks, kind, qlen, cfg, &workers)
}

/// Fig 7 CPU baselines — analytic host-side cost models over the same
/// workload accounting.

/// SWIPE (inter-sequence SSE CPU) runtime for `real_cells` at `qlen` on
/// `cores` host cores.
pub fn swipe_time(real_cells: u128, qlen: usize, cores: usize) -> f64 {
    let eff = if cores > 8 { calibration::HOST_16C_EFFICIENCY } else { 1.0 };
    let rate = calibration::SWIPE_CORE_RATE * cores as f64 * eff
        / (1.0 + calibration::SWIPE_OVERHEAD_LEN / qlen.max(1) as f64);
    real_cells as f64 / rate
}

/// BLAST+ runtime model: seeding scan over the database plus DP on the
/// cells the heuristic actually visited (measured by our blast module).
pub fn blast_time(visited_cells: u128, word_hits: u128, db_residues: u128, cores: usize) -> f64 {
    let eff = if cores > 8 { calibration::HOST_16C_EFFICIENCY } else { 1.0 };
    let scan = db_residues as f64 * calibration::BLAST_SCAN_COST_PER_RESIDUE;
    let hits = word_hits as f64 * calibration::BLAST_HIT_COST;
    let dp = visited_cells as f64 / calibration::BLAST_VISIT_RATE;
    (scan + hits + dp) / (cores as f64 * eff)
}

/// Host cores charged for the funnel's prefilter stage (the E5-2670-class
/// host that feeds the coprocessor fleet).
pub const FUNNEL_PREFILTER_CORES: usize = 16;

/// Two-stage funnel timing: the seeded prefilter screens the whole
/// database ([`blast_time`] over the *measured* heuristic work), then the
/// exact stage pays the SW device schedule scaled by the surviving
/// fraction of the database. The exact stage reuses [`simulate_search`]
/// unchanged, so the funnel's predicted speedup is consistent with exact
/// mode's own figures; `real_cells`/`padded_cells` keep describing the
/// full screened workload, so [`SimReport::gcups`] reports *effective*
/// GCUPS — the paper's Fig 7 framing of why heuristics look so fast.
#[allow(clippy::too_many_arguments)]
pub fn simulate_funnel(
    index: &Index,
    chunks: &[Chunk],
    kind: EngineKind,
    qlen: usize,
    cfg: SimConfig,
    visited_cells: u128,
    word_hits: u128,
    survivor_fraction: f64,
) -> SimReport {
    let mut rep = simulate_search(index, chunks, kind, qlen, cfg);
    let f = survivor_fraction.clamp(0.0, 1.0);
    let prefilter =
        blast_time(visited_cells, word_hits, index.total_residues, FUNNEL_PREFILTER_CORES);
    rep.makespan = prefilter + rep.makespan * f;
    rep.compute_time = prefilter + rep.compute_time * f;
    for t in rep.device_done.iter_mut() {
        *t = prefilter + *t * f;
    }
    for t in rep.device_compute_s.iter_mut() {
        *t *= f;
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::chunk::{plan_chunks, ChunkPlanConfig};
    use crate::db::synth::{generate, SynthSpec};

    fn workload(n: usize) -> (Index, Vec<Chunk>) {
        let idx = Index::build(generate(&SynthSpec::trembl_mini(n, 77)));
        let chunks = plan_chunks(&idx, ChunkPlanConfig { target_padded_residues: 1 << 16 });
        (idx, chunks)
    }

    /// default fleet config with enough replication to fill 240 threads
    fn cfg(devices: usize) -> SimConfig {
        SimConfig { devices, replication: 400, ..SimConfig::default() }
    }

    #[test]
    fn cells_conserved() {
        let (idx, chunks) = workload(600);
        let r = simulate_search(&idx, &chunks, EngineKind::InterSP, 500, SimConfig::default());
        assert_eq!(r.real_cells, idx.total_residues * 500);
        assert_eq!(r.padded_cells, idx.padded_cells(500));
        let r2 = simulate_search(&idx, &chunks, EngineKind::InterSP, 500, cfg(1));
        assert_eq!(r2.real_cells, idx.total_residues * 500 * 400);
        assert!(r.padded_cells >= r.real_cells);
    }

    #[test]
    fn funnel_beats_exact_when_survivors_are_few() {
        let (idx, chunks) = workload(600);
        let exact = simulate_search(&idx, &chunks, EngineKind::InterSP, 500, cfg(1));
        let visited = idx.total_residues * 5; // heuristic touches ~1% of cells
        let hits = idx.total_residues / 10;
        let fast = simulate_funnel(
            &idx, &chunks, EngineKind::InterSP, 500, cfg(1), visited, hits, 0.05,
        );
        assert!(
            fast.makespan < exact.makespan / 3.0,
            "5% survivors must be >3x faster: {} vs {}",
            fast.makespan,
            exact.makespan
        );
        assert_eq!(fast.real_cells, exact.real_cells, "screened workload unchanged");
        assert!(fast.gcups() > exact.gcups(), "effective GCUPS rises");
        // a funnel that keeps everything is strictly slower than exact
        let all = simulate_funnel(
            &idx, &chunks, EngineKind::InterSP, 500, cfg(1), visited, hits, 1.0,
        );
        assert!(all.makespan > exact.makespan);
        // monotone in the survivor fraction
        let half = simulate_funnel(
            &idx, &chunks, EngineKind::InterSP, 500, cfg(1), visited, hits, 0.5,
        );
        assert!(fast.makespan < half.makespan && half.makespan < all.makespan);
    }

    #[test]
    fn device_timeline_matches_the_real_fleet_shape() {
        let (idx, chunks) = workload(600);
        let r = simulate_search(&idx, &chunks, EngineKind::InterSP, 500, cfg(4));
        let tl = r.device_timeline();
        assert_eq!(tl.len(), 4);
        for t in &tl {
            // busy split is conservative (compute + steal == device busy)
            let total_busy = t.busy_us() as f64 / 1e6;
            let modeled = r.device_compute_s[t.device];
            assert!(
                (total_busy - modeled).abs() < 2e-6 + modeled * 1e-6,
                "device {}: busy {total_busy} vs modeled {modeled}",
                t.device
            );
            // idle + busy never exceeds one makespan by more than
            // rounding (busy happens inside the batch walls)
            assert!(t.utilization() <= 1.0);
            assert!((t.busy_us() + t.idle_us) as f64 / 1e6 <= r.makespan + 2e-6);
        }
        // a 4-device fleet with a shared pool keeps everyone >50% busy
        let mean = tl.iter().map(DeviceTimeline::utilization).sum::<f64>() / tl.len() as f64;
        assert!(mean > 0.5, "mean utilization {mean}");
    }

    #[test]
    fn single_device_gcups_in_paper_band() {
        let (idx, chunks) = workload(2000);
        for (qlen, lo, hi) in [(144usize, 35.0, 52.0), (1000, 48.0, 60.0), (5478, 52.0, 62.0)] {
            let r = simulate_search(&idx, &chunks, EngineKind::InterSP, qlen, cfg(1));
            let g = r.gcups();
            assert!((lo..hi).contains(&g), "q={qlen}: {g} GCUPS");
        }
    }

    #[test]
    fn scaling_near_linear_on_big_db() {
        let (idx, chunks) = workload(3000);
        let base = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1));
        for n in [2usize, 4] {
            let r = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(n));
            let speedup = base.makespan / r.makespan;
            assert!(
                speedup > 0.85 * n as f64 && speedup <= n as f64 + 1e-9,
                "{n} devices: speedup {speedup}"
            );
            assert_eq!(r.chunks_per_device.iter().sum::<usize>(), chunks.len());
        }
    }

    #[test]
    fn small_db_scales_worse_than_big_db() {
        // Fig 8 mechanism: offload overhead doesn't amortize on a small DB
        let (small_idx, small_chunks) = workload(150);
        let (big_idx, big_chunks) = workload(3000);
        let sp4 = |idx: &Index, chunks: &[Chunk]| {
            let c1 = simulate_search(idx, chunks, EngineKind::InterSP, 464, cfg(1));
            let c4 = simulate_search(idx, chunks, EngineKind::InterSP, 464, cfg(4));
            c1.makespan / c4.makespan
        };
        let small = sp4(&small_idx, &small_chunks);
        let big = sp4(&big_idx, &big_chunks);
        assert!(small < big, "small-db speedup {small} should trail big-db {big}");
    }

    #[test]
    fn offload_fraction_higher_for_short_queries() {
        let (idx, chunks) = workload(800);
        let short = simulate_search(&idx, &chunks, EngineKind::InterSP, 144, cfg(1));
        let long = simulate_search(&idx, &chunks, EngineKind::InterSP, 5478, cfg(1));
        assert!(short.offload_fraction() > long.offload_fraction());
    }

    #[test]
    fn free_offload_beats_default() {
        let (idx, chunks) = workload(400);
        let cfg_free = SimConfig { offload: OffloadModel::free(), ..cfg(1) };
        let free = simulate_search(&idx, &chunks, EngineKind::InterSP, 300, cfg_free);
        let paid = simulate_search(&idx, &chunks, EngineKind::InterSP, 300, cfg(1));
        assert!(free.makespan < paid.makespan);
        assert_eq!(free.offload_time, 0.0);
    }

    #[test]
    fn narrow_tier_speeds_up_sim_and_rescore_costs() {
        let (idx, chunks) = workload(800);
        let full = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1));
        let narrow = simulate_search(
            &idx,
            &chunks,
            EngineKind::InterSP,
            1000,
            SimConfig { precision: Precision::I16, ..cfg(1) },
        );
        assert!(
            narrow.makespan < full.makespan,
            "i16 tier must be faster: {} vs {}",
            narrow.makespan,
            full.makespan
        );
        // a high rescore fraction erodes the narrow-tier advantage
        let rescored = simulate_search(
            &idx,
            &chunks,
            EngineKind::InterSP,
            1000,
            SimConfig { precision: Precision::I16, rescore_fraction: 0.5, ..cfg(1) },
        );
        assert!(rescored.makespan > narrow.makespan);
        // striped has no narrow tier: i16 with no rescore changes nothing
        let intra_full = simulate_search(&idx, &chunks, EngineKind::IntraQP, 1000, cfg(1));
        let intra_narrow = simulate_search(
            &idx,
            &chunks,
            EngineKind::IntraQP,
            1000,
            SimConfig { precision: Precision::I16, ..cfg(1) },
        );
        assert!((intra_full.makespan - intra_narrow.makespan).abs() < 1e-12);
        // cells accounting is tier-independent
        assert_eq!(narrow.real_cells, full.real_cells);
        assert_eq!(narrow.padded_cells, full.padded_cells);
    }

    #[test]
    fn sharded_sim_tracks_pooled_and_scales() {
        use crate::db::chunk::partition_chunks;
        let (idx, chunks) = workload(3000);
        assert!(chunks.len() >= 8, "need several chunks, got {}", chunks.len());
        let base =
            simulate_sharded_search(&idx, &chunks, &partition_chunks(&chunks, 1), EngineKind::InterSP, 1000, cfg(1), true);
        let pooled1 = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1));
        // one device: sharded == pooled (same chunks, one queue)
        assert!((base.makespan - pooled1.makespan).abs() < 1e-9);
        assert_eq!(base.real_cells, pooled1.real_cells);
        for n in [2usize, 4] {
            let shards = partition_chunks(&chunks, n);
            let r = simulate_sharded_search(&idx, &chunks, &shards, EngineKind::InterSP, 1000, cfg(n), true);
            assert_eq!(r.chunks_per_device.iter().sum::<usize>(), chunks.len());
            assert_eq!(r.real_cells, pooled1.real_cells, "cells conserved");
            let speedup = base.makespan / r.makespan;
            assert!(speedup > 0.8 * n as f64, "{n} devices: sharded speedup {speedup}");
            // LPT shards + stealing stay within a whisker of the pooled
            // greedy schedule
            let pooled = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(n));
            assert!(
                r.makespan <= pooled.makespan * 1.25,
                "{n} devices: sharded {} vs pooled {}",
                r.makespan,
                pooled.makespan
            );
        }
    }

    #[test]
    fn stealing_rescues_a_degenerate_shard_plan() {
        // all chunks piled on device 0: without stealing the other
        // devices retire idle and the makespan degrades to 1-device;
        // with stealing they raid device 0's queue and the fleet
        // rebalances — the straggler-tail mechanism, deterministically
        let (idx, chunks) = workload(2000);
        assert!(chunks.len() >= 8);
        let devices = 4;
        let mut shards: Vec<Vec<usize>> = vec![Vec::new(); devices];
        shards[0] = (0..chunks.len()).collect();
        let no_steal = simulate_sharded_search(
            &idx, &chunks, &shards, EngineKind::InterSP, 1000, cfg(devices), false,
        );
        let stolen = simulate_sharded_search(
            &idx, &chunks, &shards, EngineKind::InterSP, 1000, cfg(devices), true,
        );
        assert_eq!(no_steal.chunks_per_device, {
            let mut v = vec![0; devices];
            v[0] = chunks.len();
            v
        });
        assert!(no_steal.stolen_chunks.iter().all(|&s| s == 0));
        assert!(
            no_steal.makespan > 2.0 * stolen.makespan,
            "stealing must rebalance: {} vs {}",
            no_steal.makespan,
            stolen.makespan
        );
        assert!(stolen.stolen_chunks.iter().skip(1).any(|&s| s > 0), "{:?}", stolen.stolen_chunks);
        assert_eq!(stolen.chunks_per_device.iter().sum::<usize>(), chunks.len());
        assert_eq!(stolen.real_cells, no_steal.real_cells);
    }

    #[test]
    fn rated_sharded_with_uniform_rates_is_identical() {
        use crate::db::chunk::partition_chunks;
        let (idx, chunks) = workload(1500);
        for n in [1usize, 3] {
            let shards = partition_chunks(&chunks, n);
            let plain = simulate_sharded_search(
                &idx, &chunks, &shards, EngineKind::InterSP, 729, cfg(n), true,
            );
            let rated = simulate_sharded_rates(
                &idx, &chunks, &shards, EngineKind::InterSP, 729, cfg(n), true,
                &vec![1.0; n],
            );
            assert_eq!(plain.makespan, rated.makespan, "{n} devices");
            assert_eq!(plain.device_done, rated.device_done);
            assert_eq!(plain.chunks_per_device, rated.chunks_per_device);
            assert_eq!(plain.stolen_chunks, rated.stolen_chunks);
        }
    }

    #[test]
    fn skewed_fleet_weighted_shards_and_stealing_rescue_the_straggler() {
        use crate::db::chunk::{partition_chunks, partition_chunks_weighted};
        let (idx, chunks) = workload(2000);
        assert!(chunks.len() >= 8);
        let rates = [1.0, 1.0, 0.25];
        let run = |shards: &[Vec<usize>], steal| {
            simulate_sharded_rates(
                &idx, &chunks, shards, EngineKind::InterSP, 1000, cfg(3), steal, &rates,
            )
        };
        let unweighted = partition_chunks(&chunks, 3);
        let weighted = partition_chunks_weighted(&chunks, &rates);
        let blind = run(&unweighted, false);
        let balanced = run(&weighted, false);
        let stolen = run(&weighted, true);
        // rate-blind LPT makes the quarter-rate device the straggler;
        // weighting the split by rate must cut the makespan outright
        assert!(
            balanced.makespan < blind.makespan * 0.75,
            "weighted {} vs rate-blind {}",
            balanced.makespan,
            blind.makespan
        );
        // stealing can only help further
        assert!(stolen.makespan <= balanced.makespan * (1.0 + 1e-9));
        // the slow device processed fewer chunks than either fast one
        assert!(
            stolen.chunks_per_device[2] < stolen.chunks_per_device[0]
                && stolen.chunks_per_device[2] < stolen.chunks_per_device[1],
            "{:?}",
            stolen.chunks_per_device
        );
        // conservation is rate-independent
        assert_eq!(blind.real_cells, stolen.real_cells);
        assert_eq!(blind.padded_cells, stolen.padded_cells);
        assert_eq!(
            stolen.chunks_per_device.iter().sum::<usize>(),
            chunks.len()
        );
    }

    #[test]
    fn rate_aware_steal_targets_the_slow_victim() {
        // pile everything on the slow device: with rate-aware stealing
        // the fast devices must take most of the work off it
        let (idx, chunks) = workload(1500);
        assert!(chunks.len() >= 8);
        let rates = [1.0, 1.0, 0.2];
        let mut shards: Vec<Vec<usize>> = vec![Vec::new(); 3];
        shards[2] = (0..chunks.len()).collect();
        let stolen = simulate_sharded_rates(
            &idx, &chunks, &shards, EngineKind::InterSP, 1000, cfg(3), true, &rates,
        );
        let pinned = simulate_sharded_rates(
            &idx, &chunks, &shards, EngineKind::InterSP, 1000, cfg(3), false, &rates,
        );
        assert!(
            pinned.makespan > 3.0 * stolen.makespan,
            "stealing must rescue the loaded straggler: {} vs {}",
            pinned.makespan,
            stolen.makespan
        );
        let raided: usize = stolen.stolen_chunks.iter().take(2).sum();
        assert!(raided > 0, "{:?}", stolen.stolen_chunks);
        assert!(
            stolen.chunks_per_device[2] < chunks.len() / 2,
            "slow device must not keep the bulk: {:?}",
            stolen.chunks_per_device
        );
    }

    fn tune_cfg(warmup: u64) -> crate::tune::TuneConfig {
        crate::tune::TuneConfig {
            enabled: true,
            warmup_batches: warmup,
            ewma_alpha: 0.5,
            dead_band: 0.1,
            min_batches_between_reshards: 2,
        }
    }

    #[test]
    fn calibrated_sim_converges_on_miscalibrated_fleet() {
        // the acceptance scenario: configured [1,1,1], truly [1,1,0.25].
        // Bounded-length workload (tiny preset, the CI bench regime):
        // calibration's makespan win lives where chunks are coarse
        // relative to the fleet and no single mega-chunk bounds the
        // batch from below — on TrEMBL-shaped length tails the longest
        // sequences' chunk dominates any split and stealing alone is
        // already near-ideal (which the drift test below covers).
        let idx = Index::build(generate(&SynthSpec::tiny(600, 2014)));
        let chunks = plan_chunks(&idx, ChunkPlanConfig { target_padded_residues: 4096 });
        assert!(chunks.len() >= 8, "need a real plan, got {}", chunks.len());
        let scenario = CalibratedScenario {
            configured: vec![1.0; 3],
            true_rates: vec![(0, vec![1.0, 1.0, 0.25])],
            batches: 8,
            tune: tune_cfg(2),
        };
        let r = simulate_calibrated_search(
            &idx, &chunks, EngineKind::InterSP, 1000, cfg(3), &scenario,
        );
        assert_eq!(r.batches.len(), 8);
        // re-weights within warmup_batches: the warmup boundary adopts
        assert!(
            r.batches[1].resharded_after,
            "warmup boundary must adopt the measured rates: {:?}",
            r.batches.iter().map(|b| b.resharded_after).collect::<Vec<_>>()
        );
        assert!(r.resharded_total >= 1);
        // converged: the steady-state batch is within 1.2x of the
        // setup + Σwork/Σrate ideal (the acceptance bound)
        let last = r.batches.last().unwrap();
        assert!(
            last.makespan <= 1.2 * last.ideal,
            "converged batch {} vs ideal {}",
            last.makespan,
            last.ideal
        );
        // and the blind warmup batch was materially worse
        let first = &r.batches[0];
        assert!(
            first.makespan > 1.25 * last.makespan,
            "calibration gain: blind {} vs converged {}",
            first.makespan,
            last.makespan
        );
        // the estimate recovered the true ratio
        let ratio = r.calibrated[2] / r.calibrated[0];
        assert!((0.15..=0.35).contains(&ratio), "calibrated ratio {ratio}: {:?}", r.calibrated);
        assert!(
            last.believed[2] < last.believed[0] * 0.5,
            "steady state runs on measured rates: {:?}",
            last.believed
        );
        assert_eq!(first.believed, vec![1.0; 3], "first batch runs on the configured rates");
        assert!(r.gcups() > 0.0);
    }

    #[test]
    fn calibrated_sim_detects_mid_run_drift() {
        // truth starts uniform (configured is right), then device 2
        // degrades to quarter rate at batch 4 — the dead-band holds
        // during the healthy phase and the streak detector re-shards
        // within a few batches of the onset
        let (idx, chunks) = workload(1500);
        assert!(chunks.len() >= 8);
        let scenario = CalibratedScenario {
            configured: vec![1.0; 3],
            true_rates: vec![(0, vec![1.0; 3]), (4, vec![1.0, 1.0, 0.25])],
            batches: 12,
            tune: tune_cfg(2),
        };
        let r = simulate_calibrated_search(
            &idx, &chunks, EngineKind::InterSP, 1000, cfg(3), &scenario,
        );
        assert!(
            r.batches[..4].iter().all(|b| !b.resharded_after),
            "a correctly configured fleet must not re-shard: {:?}",
            r.batches.iter().map(|b| b.resharded_after).collect::<Vec<_>>()
        );
        let when = r
            .batches
            .iter()
            .position(|b| b.resharded_after)
            .expect("sustained drift must trigger a re-shard");
        assert!((4..=8).contains(&when), "re-sharded after batch {when}");
        let last = r.batches.last().unwrap();
        assert!(
            last.makespan <= 1.2 * last.ideal,
            "post-drift convergence: {} vs ideal {}",
            last.makespan,
            last.ideal
        );
        assert!(last.believed[2] < last.believed[0] * 0.5, "{:?}", last.believed);
    }

    #[test]
    fn calibrated_sim_uniform_truth_holds_steady() {
        // truth == configured: every batch is bit-identical and the
        // tuner never re-shards (the dead-band absorbs scheduling noise)
        let (idx, chunks) = workload(1200);
        let scenario = CalibratedScenario {
            configured: vec![1.0; 2],
            true_rates: vec![(0, vec![1.0; 2])],
            batches: 5,
            tune: tune_cfg(2),
        };
        let r = simulate_calibrated_search(
            &idx, &chunks, EngineKind::InterSP, 729, cfg(2), &scenario,
        );
        assert_eq!(r.resharded_total, 0, "healthy fleet must hold steady");
        for b in &r.batches {
            assert_eq!(b.makespan, r.batches[0].makespan, "steady batches are bit-identical");
            assert_eq!(b.believed, vec![1.0; 2]);
        }
        // calibrated estimate sits inside the dead-band around 1.0
        for &c in &r.calibrated {
            assert!((c - 1.0).abs() < 0.1, "{:?}", r.calibrated);
        }
    }

    #[test]
    fn mismodeled_with_true_beliefs_is_the_rated_sim() {
        use crate::db::chunk::partition_chunks_weighted;
        let (idx, chunks) = workload(1000);
        let rates = [1.0, 0.5, 0.25];
        let shards = partition_chunks_weighted(&chunks, &rates);
        let a = simulate_sharded_rates(
            &idx, &chunks, &shards, EngineKind::InterSP, 500, cfg(3), true, &rates,
        );
        let b = simulate_sharded_mismodeled(
            &idx, &chunks, &shards, EngineKind::InterSP, 500, cfg(3), true, &rates, &rates,
        );
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.device_done, b.device_done);
        assert_eq!(a.stolen_chunks, b.stolen_chunks);
        // per-device gauges account for everything exactly once
        assert_eq!(a.device_padded_cells.iter().sum::<u128>(), a.padded_cells);
        assert!((a.device_compute_s.iter().sum::<f64>() - a.compute_time).abs() < 1e-9);
        // believing uniform on a skewed fleet changes the schedule
        let c = simulate_sharded_mismodeled(
            &idx, &chunks, &shards, EngineKind::InterSP, 500, cfg(3), true, &rates,
            &[1.0, 1.0, 1.0],
        );
        assert_eq!(c.real_cells, a.real_cells, "conservation is belief-independent");
        assert_eq!(c.chunks_per_device.iter().sum::<usize>(), chunks.len());
    }

    #[test]
    fn intra_slower_than_inter_in_sim() {
        let (idx, chunks) = workload(800);
        let sp = simulate_search(&idx, &chunks, EngineKind::InterSP, 729, cfg(1));
        let iq = simulate_search(&idx, &chunks, EngineKind::IntraQP, 729, cfg(1));
        assert!(iq.makespan > sp.makespan);
    }

    #[test]
    fn hybrid_beats_phi_only_and_conserves_cells() {
        // §V extension: SWIPE-class host cores join the pool. 16 cores
        // (~150 GCUPS) outgun a second Phi (~55), 2 cores (~19) land in
        // between — both orderings must emerge from the pool simulation.
        let (idx, chunks) = workload(2000);
        let phi1 = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1));
        let phi2 = simulate_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(2));
        let hybrid =
            simulate_hybrid_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1), 16);
        assert!(hybrid.makespan < phi1.makespan, "hybrid must beat phi-only");
        assert!(hybrid.makespan < phi2.makespan, "1 Phi + 16 cores > 2 Phi");
        let small_hybrid =
            simulate_hybrid_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1), 2);
        assert!(small_hybrid.makespan < phi1.makespan);
        assert!(small_hybrid.makespan > phi2.makespan, "2 host cores < a second Phi");
        assert_eq!(hybrid.real_cells, phi1.real_cells);
        assert_eq!(hybrid.chunks_per_device.len(), 2);
        assert_eq!(hybrid.chunks_per_device.iter().sum::<usize>(), chunks.len());
        // zero host cores degrades to the plain simulation
        let same = simulate_hybrid_search(&idx, &chunks, EngineKind::InterSP, 1000, cfg(1), 0);
        assert!((same.makespan - phi1.makespan).abs() < 1e-9);
    }

    #[test]
    fn cpu_baseline_models_anchor() {
        // SWIPE: 13.2e9 residues × q=1000 on 8 cores ≈ 80 GCUPS
        let cells = 13_200_000_000u128 * 1000;
        let t8 = swipe_time(cells, 1000, 8);
        let g8 = crate::util::gcups(cells, t8);
        assert!((75.0..85.0).contains(&g8), "swipe 8c {g8}");
        let t16 = swipe_time(cells, 1000, 16);
        let g16 = crate::util::gcups(cells, t16);
        assert!((140.0..160.0).contains(&g16), "swipe 16c {g16}");
        // BLAST: visiting 2% of cells must yield far higher effective GCUPS
        let visited = cells / 50;
        let tb = blast_time(visited, 13_200_000_000 * 2, 13_200_000_000, 8);
        let gb = crate::util::gcups(cells, tb);
        assert!(gb > g8, "blast effective {gb} vs swipe {g8}");
    }
}

//! OpenMP-style loop schedulers (paper §III.A).
//!
//! The paper parallelizes the per-chunk alignment loop over 240 device
//! threads and evaluates the four OpenMP policies, finding `static` worst
//! (irregular iteration costs from varying subject lengths) and `guided`
//! best by a slight margin — which we reproduce as the `ablation_sched`
//! bench. The same policies drive the discrete-event simulator and the
//! real host-thread chunk pool.

/// OpenMP loop scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Pre-split into equal contiguous blocks, one per thread.
    Static,
    /// Threads grab one iteration at a time from a shared counter.
    Dynamic,
    /// Threads grab `⌈remaining / 2T⌉` iterations (shrinking grants).
    Guided,
    /// Implementation-defined; like OpenMP runtimes we map it to guided.
    Auto,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "static" => Some(Policy::Static),
            "dynamic" => Some(Policy::Dynamic),
            "guided" => Some(Policy::Guided),
            "auto" => Some(Policy::Auto),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Static => "static",
            Policy::Dynamic => "dynamic",
            Policy::Guided => "guided",
            Policy::Auto => "auto",
        }
    }

    pub const ALL: [Policy; 4] = [Policy::Static, Policy::Dynamic, Policy::Guided, Policy::Auto];
}

/// Serialization cost of one scheduling grant: the shared loop counter /
/// work queue is a central atomic that 240 device threads contend on.
/// Dynamic scheduling pays it per iteration; guided amortizes it over
/// shrinking blocks — which is exactly why the paper finds guided ahead
/// of dynamic "albeit by a slight margin" (§III.A).
pub const GRANT_OVERHEAD_S: f64 = 2.5e-6;

/// Deterministic list-scheduling simulation: given per-item costs and `t`
/// threads, return the makespan under the policy (plus per-thread busy
/// time for utilization accounting).
///
/// This is the core of the Xeon Phi discrete-event model: within a chunk
/// the 240 device threads execute the alignment loop under the chosen
/// OpenMP schedule; the simulated chunk latency is the policy's makespan.
pub fn simulate_schedule(costs: &[f64], t: usize, policy: Policy) -> ScheduleOutcome {
    assert!(t >= 1);
    match policy {
        Policy::Static => simulate_static(costs, t),
        Policy::Dynamic => simulate_chunked(costs, t, |_remaining, _t| 1),
        Policy::Guided | Policy::Auto => simulate_chunked(costs, t, |remaining, t| {
            (remaining.div_ceil(2 * t)).max(1)
        }),
    }
}

/// Outcome of one scheduled loop.
#[derive(Clone, Debug)]
pub struct ScheduleOutcome {
    pub makespan: f64,
    pub busy: Vec<f64>,
    /// Number of scheduling grants (work-queue interactions).
    pub grants: usize,
}

impl ScheduleOutcome {
    /// Mean utilization = Σbusy / (T × makespan).
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 1.0;
        }
        self.busy.iter().sum::<f64>() / (self.busy.len() as f64 * self.makespan)
    }
}

fn simulate_static(costs: &[f64], t: usize) -> ScheduleOutcome {
    // OpenMP static: contiguous blocks of ⌈n/t⌉
    let n = costs.len();
    let block = n.div_ceil(t.max(1)).max(1);
    let mut busy = vec![0.0; t];
    for (b, chunk) in costs.chunks(block).enumerate() {
        busy[b % t] += chunk.iter().sum::<f64>();
    }
    let makespan = busy.iter().cloned().fold(0.0, f64::max);
    ScheduleOutcome { makespan, busy, grants: n.div_ceil(block) }
}

fn simulate_chunked(
    costs: &[f64],
    t: usize,
    grant: impl Fn(usize, usize) -> usize,
) -> ScheduleOutcome {
    // event-driven: threads pull shrinking grants when they go idle; the
    // grant itself serializes through the shared counter (central lock)
    let n = costs.len();
    let mut busy = vec![0.0; t];
    let mut clock = vec![0.0f64; t]; // next-free time per thread
    let mut lock_free_at = 0.0f64;
    let mut next = 0usize;
    let mut grants = 0usize;
    while next < n {
        // earliest-free thread takes the next grant
        let (ti, _) = clock
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let take = grant(n - next, t).min(n - next);
        let cost: f64 = costs[next..next + take].iter().sum();
        let start = clock[ti].max(lock_free_at);
        lock_free_at = start + GRANT_OVERHEAD_S;
        clock[ti] = start + GRANT_OVERHEAD_S + cost;
        busy[ti] += cost;
        next += take;
        grants += 1;
    }
    let makespan = clock.iter().cloned().fold(0.0, f64::max);
    ScheduleOutcome { makespan, busy, grants }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn skewed_costs(n: usize, seed: u64) -> Vec<f64> {
        // length-sorted ascending like the index: late items much bigger
        let mut rng = Rng::new(seed);
        let mut v: Vec<f64> = (0..n).map(|i| (i as f64 + 1.0) * (0.8 + 0.4 * rng.f64())).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    #[test]
    fn makespan_bounds_hold() {
        let costs = skewed_costs(500, 3);
        let total: f64 = costs.iter().sum();
        let maxc = costs.iter().cloned().fold(0.0, f64::max);
        for policy in Policy::ALL {
            let o = simulate_schedule(&costs, 8, policy);
            let lower = (total / 8.0).max(maxc);
            assert!(o.makespan >= lower - 1e-9, "{policy:?}: {} < {lower}", o.makespan);
            assert!(o.makespan <= total + 1e-9, "{policy:?}");
            let busy_sum: f64 = o.busy.iter().sum();
            assert!((busy_sum - total).abs() < 1e-6, "{policy:?} conservation");
        }
    }

    #[test]
    fn static_worst_on_sorted_irregular_loop() {
        // the paper's observation: static scheduling suffers on the
        // ascending-length loop because the last block holds all the
        // long alignments
        let costs = skewed_costs(960, 5);
        let st = simulate_schedule(&costs, 16, Policy::Static).makespan;
        let dy = simulate_schedule(&costs, 16, Policy::Dynamic).makespan;
        let gu = simulate_schedule(&costs, 16, Policy::Guided).makespan;
        assert!(st > dy, "static {st} should be worse than dynamic {dy}");
        assert!(st > gu, "static {st} vs guided {gu}");
    }

    #[test]
    fn guided_fewer_grants_than_dynamic() {
        let costs = skewed_costs(1000, 7);
        let dy = simulate_schedule(&costs, 16, Policy::Dynamic);
        let gu = simulate_schedule(&costs, 16, Policy::Guided);
        assert!(gu.grants < dy.grants, "guided {} vs dynamic {}", gu.grants, dy.grants);
        assert_eq!(dy.grants, 1000);
    }

    #[test]
    fn auto_is_guided() {
        let costs = skewed_costs(300, 9);
        let a = simulate_schedule(&costs, 8, Policy::Auto);
        let g = simulate_schedule(&costs, 8, Policy::Guided);
        assert_eq!(a.makespan, g.makespan);
        assert_eq!(a.grants, g.grants);
    }

    #[test]
    fn single_thread_makespan_is_total() {
        let costs = skewed_costs(50, 11);
        let total: f64 = costs.iter().sum();
        for policy in Policy::ALL {
            let o = simulate_schedule(&costs, 1, policy);
            let ovh = if policy == Policy::Static {
                0.0
            } else {
                o.grants as f64 * GRANT_OVERHEAD_S
            };
            assert!((o.makespan - total - ovh).abs() < 1e-9, "{policy:?}");
        }
    }

    #[test]
    fn empty_loop() {
        for policy in Policy::ALL {
            let o = simulate_schedule(&[], 4, policy);
            assert_eq!(o.makespan, 0.0);
            assert_eq!(o.grants, 0);
        }
    }

    #[test]
    fn utilization_in_unit_range() {
        let costs = skewed_costs(200, 13);
        for policy in Policy::ALL {
            let o = simulate_schedule(&costs, 32, policy);
            let u = o.utilization();
            assert!((0.0..=1.0 + 1e-9).contains(&u), "{policy:?} {u}");
        }
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(Policy::parse("guided"), Some(Policy::Guided));
        assert_eq!(Policy::parse("STATIC"), Some(Policy::Static));
        assert_eq!(Policy::parse("nope"), None);
    }
}

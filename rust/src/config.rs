//! Configuration system — a strict TOML subset (sections, `key = value`
//! with strings / integers / floats / booleans, `#` comments) parsed into
//! a typed [`SwaphiConfig`], overridable from CLI flags. No external
//! crates (nothing TOML-ish is vendored), so the parser lives here and is
//! tested like any other substrate.
//!
//! Example `swaphi.toml`:
//! ```toml
//! [scoring]
//! matrix = "BLOSUM62"
//! gap_open = 10
//! gap_extend = 2
//!
//! [search]
//! engine = "intersp"      # intersp | interqp | intraqp | scalar
//! backend = "native"      # native | pjrt
//! precision = "auto"      # auto | i16 | i32 (score-lane tier)
//! mode = "exact"          # exact | fast | auto (two-stage funnel)
//! auto_fast_threshold = 50000  # db size at which auto flips to fast
//! report = "score"        # score | coord | full (per-hit alignment detail)
//! report_cell_cap = 16000000   # traceback DP cell budget per hit pair
//! devices = 4             # legacy spelling of devices.count
//! policy = "guided"       # static | dynamic | guided | auto
//! top_k = 10
//! chunk_residues = 524288
//!
//! [devices]
//! count = 4               # simulated coprocessors (wins over search.devices)
//! steal = true            # work stealing between device queues
//! rates = [1.0, 1.0, 1.0, 0.25]  # relative per-device speeds (heterogeneous fleet)
//! # handicap = [1.0, 4.0]        # observed-time multipliers (test/demo skew injector)
//!
//! [tune]
//! enabled = false          # online rate calibration (self-tuning fleet)
//! warmup_batches = 3       # measure-only batches before the first adoption
//! ewma_alpha = 0.3         # EWMA weight of the newest throughput observation
//! dead_band = 0.15         # calibrated/adopted ratio band treated as "in tune"
//! min_batches_between_reshards = 2
//!
//! [sim]
//! enabled = true
//! threads_per_device = 240
//! replication = 400
//!
//! [server]
//! listen = "127.0.0.1:7878"   # or "unix:/run/swaphi.sock"
//! queue_capacity = 256        # admission bound (backpressure)
//! max_batch = 32              # largest coalesced batch
//! batch_window_ms = 4         # how long a batch is held open
//! cache_entries = 1024        # result cache (0 disables)
//! default_deadline_ms = 30000
//! ```

use crate::align::{EngineKind, Precision};
use crate::coordinator::{ReportLevel, SearchConfig, SearchMode};
use crate::db::chunk::ChunkPlanConfig;
use crate::matrices::Scoring;
use crate::phi::sched::Policy;
use crate::phi::sim::SimConfig;
use crate::tune::TuneConfig;
use std::collections::BTreeMap;
use std::path::Path;

/// A raw parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    /// A single-line `[a, b, c]` list of scalars (no nesting; elements
    /// must not contain commas).
    List(Vec<Value>),
}

impl Value {
    fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::List(_) => "list",
        }
    }
}

/// Parsed `section.key -> value` table.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    entries: BTreeMap<String, Value>,
}

impl RawConfig {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> anyhow::Result<RawConfig> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = strip_comment(raw_line).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                anyhow::ensure!(!name.is_empty(), "line {}: empty section name", lineno + 1);
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            entries.insert(full, parse_value(value.trim(), lineno + 1)?);
        }
        Ok(RawConfig { entries })
    }

    pub fn from_file(path: impl AsRef<Path>) -> anyhow::Result<RawConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Override (or add) one dotted key, parsing the value like TOML.
    pub fn set(&mut self, dotted: &str, value: &str) -> anyhow::Result<()> {
        self.entries.insert(dotted.to_string(), parse_value(value, 0)?);
        Ok(())
    }

    pub fn get(&self, dotted: &str) -> Option<&Value> {
        self.entries.get(dotted)
    }

    pub fn str_or(&self, key: &str, default: &str) -> anyhow::Result<String> {
        match self.entries.get(key) {
            None => Ok(default.to_string()),
            Some(Value::Str(s)) => Ok(s.clone()),
            Some(v) => anyhow::bail!("{key}: expected string, got {}", v.type_name()),
        }
    }

    pub fn int_or(&self, key: &str, default: i64) -> anyhow::Result<i64> {
        match self.entries.get(key) {
            None => Ok(default),
            Some(Value::Int(i)) => Ok(*i),
            Some(v) => anyhow::bail!("{key}: expected integer, got {}", v.type_name()),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.entries.get(key) {
            None => Ok(default),
            Some(Value::Bool(b)) => Ok(*b),
            Some(v) => anyhow::bail!("{key}: expected boolean, got {}", v.type_name()),
        }
    }

    /// A floating-point value (integers widen).
    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.entries.get(key) {
            None => Ok(default),
            Some(Value::Float(f)) => Ok(*f),
            Some(Value::Int(i)) => Ok(*i as f64),
            Some(v) => anyhow::bail!("{key}: expected number, got {}", v.type_name()),
        }
    }

    /// A list of numbers (integer elements widen to float).
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> anyhow::Result<Vec<f64>> {
        match self.entries.get(key) {
            None => Ok(default.to_vec()),
            Some(Value::List(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Float(f) => Ok(*f),
                    Value::Int(i) => Ok(*i as f64),
                    v => anyhow::bail!(
                        "{key}: expected number in list, got {}",
                        v.type_name()
                    ),
                })
                .collect(),
            Some(v) => anyhow::bail!("{key}: expected list, got {}", v.type_name()),
        }
    }

    /// A list of strings. Elements must be quoted in config files when
    /// they contain characters outside the bare-identifier set — socket
    /// addresses always do (`"127.0.0.1:7901"`).
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> anyhow::Result<Vec<String>> {
        match self.entries.get(key) {
            None => Ok(default.iter().map(|s| s.to_string()).collect()),
            Some(Value::List(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Str(s) => Ok(s.clone()),
                    v => anyhow::bail!(
                        "{key}: expected string in list, got {}",
                        v.type_name()
                    ),
                })
                .collect(),
            Some(v) => anyhow::bail!("{key}: expected list, got {}", v.type_name()),
        }
    }

    /// Reject unknown keys (typo protection) given the known key set.
    pub fn validate_keys(&self, known: &[&str]) -> anyhow::Result<()> {
        for key in self.entries.keys() {
            if !known.contains(&key.as_str()) {
                anyhow::bail!(
                    "unknown config key {key:?}; known keys: {}",
                    known.join(", ")
                );
            }
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> anyhow::Result<Value> {
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: unterminated list"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::List(Vec::new()));
        }
        // name the offending element and its 1-based position: a
        // trailing comma or a doubled comma yields an empty element, the
        // classic shell/CLI slip ("1.0,1.0," / "1.0,,0.25")
        let items = inner
            .split(',')
            .enumerate()
            .map(|(i, e)| {
                let e = e.trim();
                if e.is_empty() {
                    anyhow::bail!(
                        "line {lineno}: empty list element at position {} \
                         (trailing or doubled comma?)",
                        i + 1
                    );
                }
                parse_value(e, lineno).map_err(|err| {
                    anyhow::anyhow!("list element {} ({e:?}): {err}", i + 1)
                })
            })
            .collect::<anyhow::Result<Vec<Value>>>()?;
        return Ok(Value::List(items));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare identifiers are accepted as strings (ergonomic for CLI -s k=v)
    if s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-') && !s.is_empty() {
        return Ok(Value::Str(s.to_string()));
    }
    anyhow::bail!("line {lineno}: cannot parse value {s:?}")
}

/// All recognized keys.
pub const KNOWN_KEYS: &[&str] = &[
    "scoring.matrix",
    "scoring.gap_open",
    "scoring.gap_extend",
    "search.engine",
    "search.backend",
    "search.devices",
    "search.policy",
    "search.top_k",
    "search.chunk_residues",
    "search.artifacts_dir",
    "search.precision",
    "search.mode",
    "search.auto_fast_threshold",
    "search.report",
    "search.report_cell_cap",
    "devices.count",
    "devices.steal",
    "devices.rates",
    "devices.handicap",
    "tune.enabled",
    "tune.warmup_batches",
    "tune.ewma_alpha",
    "tune.dead_band",
    "tune.min_batches_between_reshards",
    "sim.enabled",
    "sim.threads_per_device",
    "sim.replication",
    "db.preset",
    "db.n_seqs",
    "db.seed",
    "server.listen",
    "server.queue_capacity",
    "server.max_batch",
    "server.batch_window_ms",
    "server.cache_entries",
    "server.default_deadline_ms",
    "server.max_query_len",
    "server.max_connections",
    "server.slow_query_ms",
    "server.trace_ring",
    "server.slo_availability",
    "server.slo_p99_ms",
    "server.flight_dir",
    "server.flight_bundles",
    "cluster.listen",
    "cluster.backends",
    "cluster.hedge_ms",
    "cluster.retries",
    "cluster.backend_timeout_ms",
    "cluster.max_connections",
    "cluster.trace_ring",
    "cluster.slo_availability",
    "cluster.slo_p99_ms",
    "cluster.flight_dir",
    "cluster.flight_bundles",
];

/// Fully-typed SWAPHI configuration.
#[derive(Clone, Debug)]
pub struct SwaphiConfig {
    pub scoring: Scoring,
    pub engine: EngineKind,
    pub backend: String,
    pub artifacts_dir: String,
    pub devices: usize,
    pub steal: bool,
    /// Relative per-device speeds (`[devices] rates`); empty = uniform.
    pub rates: Vec<f64>,
    /// Observed-time multipliers (`[devices] handicap`) — the
    /// deterministic skew injector for calibration tests/demos; empty =
    /// none.
    pub handicap: Vec<f64>,
    /// Online rate calibration (`[tune]` section).
    pub tune_enabled: bool,
    pub tune_warmup_batches: u64,
    pub tune_ewma_alpha: f64,
    pub tune_dead_band: f64,
    pub tune_min_batches_between_reshards: u64,
    pub policy: Policy,
    pub top_k: usize,
    pub precision: Precision,
    /// Two-stage funnel selection (`search.mode`): `exact` runs full SW
    /// over the whole database, `fast` runs the seeded prefilter →
    /// exact-rescore funnel, `auto` picks `fast` above
    /// [`auto_fast_threshold`](Self::auto_fast_threshold) sequences.
    pub mode: SearchMode,
    /// Database size (sequences) above which `auto` resolves to `fast`.
    pub auto_fast_threshold: usize,
    /// Default report level (`search.report`): `score` returns ranked
    /// scores only, `coord` adds alignment endpoints/coverage/e-values,
    /// `full` adds CIGAR and identity (see `docs/alignment.md`).
    pub report: ReportLevel,
    /// Per-pair DP cell budget for the full-report traceback; pairs over
    /// it degrade to coordinates-only (`capped: true`).
    pub report_cell_cap: usize,
    pub chunk_residues: u128,
    pub sim_enabled: bool,
    pub sim_threads: usize,
    pub sim_replication: usize,
    pub db_preset: String,
    pub db_n_seqs: usize,
    pub db_seed: u64,
    pub server_listen: String,
    pub server_queue_capacity: usize,
    pub server_max_batch: usize,
    pub server_batch_window_ms: u64,
    pub server_cache_entries: usize,
    pub server_default_deadline_ms: u64,
    pub server_max_query_len: usize,
    pub server_max_connections: usize,
    /// Slow-query log threshold in milliseconds (0 disables the log).
    pub server_slow_query_ms: u64,
    /// Span-ring capacity behind the daemon's `trace` op (0 disables
    /// span recording; trace ids are still minted and echoed).
    pub server_trace_ring: usize,
    /// Availability SLO target for the daemon's `health` op.
    pub server_slo_availability: f64,
    /// Latency SLO target (request p99, milliseconds).
    pub server_slo_p99_ms: u64,
    /// Flight-recorder bundle directory; empty disables the recorder.
    pub server_flight_dir: String,
    /// Flight bundles kept on disk before the oldest is pruned.
    pub server_flight_bundles: usize,
    /// Scatter–gather router (`[cluster]` section; `swaphi route`).
    pub cluster_listen: String,
    /// Backend daemon addresses, one per partition (quoted strings in
    /// config files — addresses contain `:`).
    pub cluster_backends: Vec<String>,
    /// Fixed hedge delay in ms; 0 means auto (track the backend p99).
    pub cluster_hedge_ms: u64,
    pub cluster_retries: usize,
    pub cluster_backend_timeout_ms: u64,
    pub cluster_max_connections: usize,
    pub cluster_trace_ring: usize,
    /// Availability SLO target for the router's `health` op.
    pub cluster_slo_availability: f64,
    /// Latency SLO target (routed-search p99, milliseconds).
    pub cluster_slo_p99_ms: u64,
    /// Router flight-recorder bundle directory; empty disables it.
    pub cluster_flight_dir: String,
    pub cluster_flight_bundles: usize,
}

impl SwaphiConfig {
    /// Resolve a raw table into the typed config (paper defaults).
    pub fn from_raw(raw: &RawConfig) -> anyhow::Result<SwaphiConfig> {
        raw.validate_keys(KNOWN_KEYS)?;
        let matrix = raw.str_or("scoring.matrix", "BLOSUM62")?;
        let gap_open = raw.int_or("scoring.gap_open", 10)? as i32;
        let gap_extend = raw.int_or("scoring.gap_extend", 2)? as i32;
        let engine_s = raw.str_or("search.engine", "intersp")?;
        let policy_s = raw.str_or("search.policy", "guided")?;
        let precision_s = raw.str_or("search.precision", "auto")?;
        let mode_s = raw.str_or("search.mode", "exact")?;
        let report_s = raw.str_or("search.report", "score")?;
        let rates = {
            let rates = raw.f64_list_or("devices.rates", &[])?;
            // name the offending entry AND its 1-based position — rate
            // vectors come straight off CLI flags, where "which entry is
            // wrong" is the whole diagnosis
            for (i, &r) in rates.iter().enumerate() {
                anyhow::ensure!(
                    r.is_finite() && r > 0.0,
                    "devices.rates[{}] = {r}: each device rate must be a finite, \
                     positive number",
                    i + 1
                );
            }
            rates
        };
        let handicap = {
            let handicap = raw.f64_list_or("devices.handicap", &[])?;
            for (i, &h) in handicap.iter().enumerate() {
                anyhow::ensure!(
                    h.is_finite() && h >= 1.0,
                    "devices.handicap[{}] = {h}: each handicap is an observed-time \
                     multiplier and must be a finite number >= 1.0",
                    i + 1
                );
            }
            handicap
        };
        // devices.count is authoritative; search.devices is the
        // legacy spelling kept as its default. A rate vector without
        // an explicit count implies one device per rate; with one,
        // the lengths must agree.
        let devices = {
            let legacy = raw.int_or("search.devices", 1)?;
            let count = raw.int_or("devices.count", legacy)?.max(1) as usize;
            let explicit =
                raw.get("devices.count").is_some() || raw.get("search.devices").is_some();
            if rates.is_empty() || explicit {
                anyhow::ensure!(
                    rates.is_empty() || rates.len() == count,
                    "devices.rates has {} entries but the device count is {count}",
                    rates.len()
                );
                count
            } else {
                rates.len()
            }
        };
        anyhow::ensure!(
            handicap.is_empty() || handicap.len() == devices,
            "devices.handicap has {} entries but the device count is {devices}",
            handicap.len()
        );
        let tune_ewma_alpha = raw.f64_or("tune.ewma_alpha", 0.3)?;
        anyhow::ensure!(
            tune_ewma_alpha.is_finite() && tune_ewma_alpha > 0.0 && tune_ewma_alpha <= 1.0,
            "tune.ewma_alpha must be in (0, 1], got {tune_ewma_alpha}"
        );
        let tune_dead_band = raw.f64_or("tune.dead_band", 0.15)?;
        anyhow::ensure!(
            tune_dead_band.is_finite() && tune_dead_band > 0.0,
            "tune.dead_band must be a positive number, got {tune_dead_band}"
        );
        // SLO availability targets are fractions of requests answered
        // without error — 1.0 would make the burn rate's error budget
        // zero, so the open interval is the valid set
        let slo_target = |key: &str| -> anyhow::Result<f64> {
            let v = raw.f64_or(key, 0.999)?;
            anyhow::ensure!(
                v.is_finite() && v > 0.0 && v < 1.0,
                "{key} must be in (0, 1) exclusive, got {v}"
            );
            Ok(v)
        };
        let server_slo_availability = slo_target("server.slo_availability")?;
        let cluster_slo_availability = slo_target("cluster.slo_availability")?;
        Ok(SwaphiConfig {
            scoring: Scoring::new(&matrix, gap_open, gap_extend)?,
            engine: EngineKind::parse(&engine_s)
                .ok_or_else(|| anyhow::anyhow!("unknown engine {engine_s:?}"))?,
            backend: raw.str_or("search.backend", "native")?,
            artifacts_dir: raw.str_or("search.artifacts_dir", "artifacts")?,
            devices,
            steal: raw.bool_or("devices.steal", true)?,
            rates,
            handicap,
            tune_enabled: raw.bool_or("tune.enabled", false)?,
            tune_warmup_batches: raw.int_or("tune.warmup_batches", 3)?.max(0) as u64,
            tune_ewma_alpha,
            tune_dead_band,
            tune_min_batches_between_reshards: raw
                .int_or("tune.min_batches_between_reshards", 2)?
                .max(0) as u64,
            policy: Policy::parse(&policy_s)
                .ok_or_else(|| anyhow::anyhow!("unknown policy {policy_s:?}"))?,
            top_k: raw.int_or("search.top_k", 10)?.max(1) as usize,
            precision: Precision::parse(&precision_s)
                .ok_or_else(|| anyhow::anyhow!("unknown precision {precision_s:?} (auto|i16|i32)"))?,
            mode: SearchMode::parse(&mode_s)
                .ok_or_else(|| anyhow::anyhow!("unknown mode {mode_s:?} (exact|fast|auto)"))?,
            auto_fast_threshold: raw.int_or("search.auto_fast_threshold", 50_000)?.max(1) as usize,
            report: ReportLevel::parse(&report_s)
                .ok_or_else(|| anyhow::anyhow!("unknown report {report_s:?} (score|coord|full)"))?,
            report_cell_cap: raw.int_or("search.report_cell_cap", 16_000_000)?.max(0) as usize,
            chunk_residues: raw.int_or("search.chunk_residues", 1 << 19)?.max(1024) as u128,
            sim_enabled: raw.bool_or("sim.enabled", true)?,
            sim_threads: raw.int_or("sim.threads_per_device", 240)?.max(1) as usize,
            sim_replication: raw.int_or("sim.replication", 1)?.max(1) as usize,
            db_preset: raw.str_or("db.preset", "trembl-mini")?,
            db_n_seqs: raw.int_or("db.n_seqs", 20_000)?.max(1) as usize,
            db_seed: raw.int_or("db.seed", 2014)? as u64,
            server_listen: raw.str_or("server.listen", "127.0.0.1:7878")?,
            server_queue_capacity: raw.int_or("server.queue_capacity", 256)?.max(1) as usize,
            server_max_batch: raw.int_or("server.max_batch", 32)?.max(1) as usize,
            server_batch_window_ms: raw.int_or("server.batch_window_ms", 4)?.max(0) as u64,
            server_cache_entries: raw.int_or("server.cache_entries", 1024)?.max(0) as usize,
            server_default_deadline_ms: raw.int_or("server.default_deadline_ms", 30_000)?.max(1)
                as u64,
            server_max_query_len: raw.int_or("server.max_query_len", 50_000)?.max(1) as usize,
            server_max_connections: raw.int_or("server.max_connections", 512)?.max(1) as usize,
            server_slow_query_ms: raw.int_or("server.slow_query_ms", 0)?.max(0) as u64,
            server_trace_ring: raw.int_or("server.trace_ring", 4096)?.max(0) as usize,
            server_slo_availability,
            server_slo_p99_ms: raw.int_or("server.slo_p99_ms", 2_000)?.max(1) as u64,
            server_flight_dir: raw.str_or("server.flight_dir", "")?,
            server_flight_bundles: raw.int_or("server.flight_bundles", 8)?.max(1) as usize,
            cluster_listen: raw.str_or("cluster.listen", "127.0.0.1:7900")?,
            cluster_backends: raw.str_list_or("cluster.backends", &[])?,
            cluster_hedge_ms: raw.int_or("cluster.hedge_ms", 0)?.max(0) as u64,
            cluster_retries: raw.int_or("cluster.retries", 2)?.max(0) as usize,
            cluster_backend_timeout_ms: raw
                .int_or("cluster.backend_timeout_ms", 10_000)?
                .max(1) as u64,
            cluster_max_connections: raw.int_or("cluster.max_connections", 256)?.max(1)
                as usize,
            cluster_trace_ring: raw.int_or("cluster.trace_ring", 4096)?.max(0) as usize,
            cluster_slo_availability,
            cluster_slo_p99_ms: raw.int_or("cluster.slo_p99_ms", 2_000)?.max(1) as u64,
            cluster_flight_dir: raw.str_or("cluster.flight_dir", "")?,
            cluster_flight_bundles: raw.int_or("cluster.flight_bundles", 8)?.max(1) as usize,
        })
    }

    pub fn default_config() -> SwaphiConfig {
        Self::from_raw(&RawConfig::default()).expect("defaults are valid")
    }

    /// Materialize the daemon's [`ServerConfig`](crate::server::ServerConfig).
    pub fn server_config(&self) -> crate::server::ServerConfig {
        crate::server::ServerConfig {
            listen: self.server_listen.clone(),
            queue_capacity: self.server_queue_capacity,
            max_batch: self.server_max_batch,
            batch_window_ms: self.server_batch_window_ms,
            cache_entries: self.server_cache_entries,
            default_deadline_ms: self.server_default_deadline_ms,
            max_query_len: self.server_max_query_len,
            max_connections: self.server_max_connections,
            handle_signals: false,
            slow_query_ms: self.server_slow_query_ms,
            trace_ring: self.server_trace_ring,
            slo_availability: self.server_slo_availability,
            slo_p99_ms: self.server_slo_p99_ms,
            flight_dir: (!self.server_flight_dir.is_empty())
                .then(|| self.server_flight_dir.clone().into()),
            flight_bundles: self.server_flight_bundles,
        }
    }

    /// Materialize the coordinator's [`SearchConfig`].
    pub fn search_config(&self) -> SearchConfig {
        SearchConfig {
            devices: self.devices,
            steal: self.steal,
            rates: self.rates.clone(),
            chunk: ChunkPlanConfig { target_padded_residues: self.chunk_residues },
            top_k: self.top_k,
            precision: self.precision,
            mode: self.mode,
            auto_fast_threshold: self.auto_fast_threshold,
            report: self.report,
            report_cell_cap: self.report_cell_cap,
            // 0 = "this index is the whole database"; cluster backends
            // overwrite it from their `.pmeta` sidecar at daemon startup
            db_residues: 0,
            sim: self.sim_enabled.then(|| SimConfig {
                devices: self.devices,
                threads_per_device: self.sim_threads,
                policy: self.policy,
                replication: self.sim_replication,
                ..Default::default()
            }),
            tune: self.tune_config(),
            handicap: self.handicap.clone(),
        }
    }

    /// Materialize the router's [`RouterConfig`](crate::cluster::RouterConfig).
    pub fn router_config(&self) -> crate::cluster::RouterConfig {
        crate::cluster::RouterConfig {
            listen: self.cluster_listen.clone(),
            backends: self.cluster_backends.clone(),
            hedge_ms: (self.cluster_hedge_ms > 0).then_some(self.cluster_hedge_ms),
            retries: self.cluster_retries,
            backend_timeout_ms: self.cluster_backend_timeout_ms,
            max_connections: self.cluster_max_connections,
            handle_signals: false,
            trace_ring: self.cluster_trace_ring,
            slo_availability: self.cluster_slo_availability,
            slo_p99_ms: self.cluster_slo_p99_ms,
            flight_dir: (!self.cluster_flight_dir.is_empty())
                .then(|| self.cluster_flight_dir.clone().into()),
            flight_bundles: self.cluster_flight_bundles,
        }
    }

    /// Materialize the calibration subsystem's [`TuneConfig`].
    pub fn tune_config(&self) -> TuneConfig {
        TuneConfig {
            enabled: self.tune_enabled,
            warmup_batches: self.tune_warmup_batches,
            ewma_alpha: self.tune_ewma_alpha,
            dead_band: self.tune_dead_band,
            min_batches_between_reshards: self.tune_min_batches_between_reshards,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let raw = RawConfig::parse(
            r#"
            # comment
            [scoring]
            matrix = "BLOSUM50"   # inline comment
            gap_open = 12
            [sim]
            enabled = false
            "#,
        )
        .unwrap();
        assert_eq!(raw.get("scoring.matrix"), Some(&Value::Str("BLOSUM50".into())));
        assert_eq!(raw.get("scoring.gap_open"), Some(&Value::Int(12)));
        assert_eq!(raw.get("sim.enabled"), Some(&Value::Bool(false)));
    }

    #[test]
    fn typed_config_defaults_match_paper() {
        let cfg = SwaphiConfig::default_config();
        assert_eq!(cfg.scoring.name, "BLOSUM62");
        assert_eq!(cfg.scoring.gap_open, 10);
        assert_eq!(cfg.scoring.gap_extend, 2);
        assert_eq!(cfg.engine, EngineKind::InterSP);
        assert_eq!(cfg.policy, Policy::Guided);
        assert_eq!(cfg.precision, Precision::Auto);
        assert_eq!(cfg.sim_threads, 240);
    }

    #[test]
    fn precision_key_parses_and_rejects() {
        let mut raw = RawConfig::default();
        raw.set("search.precision", "i16").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.precision, Precision::I16);
        assert_eq!(cfg.search_config().precision, Precision::I16);
        raw.set("search.precision", "i128").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("precision"), "{err}");
    }

    #[test]
    fn mode_key_parses_and_rejects() {
        let cfg = SwaphiConfig::default_config();
        assert_eq!(cfg.mode, SearchMode::Exact, "exact is the default");
        assert_eq!(cfg.auto_fast_threshold, 50_000);
        let mut raw = RawConfig::default();
        raw.set("search.mode", "fast").unwrap();
        raw.set("search.auto_fast_threshold", "1000").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.mode, SearchMode::Fast);
        let sc = cfg.search_config();
        assert_eq!(sc.mode, SearchMode::Fast);
        assert_eq!(sc.auto_fast_threshold, 1000);
        raw.set("search.mode", "auto").unwrap();
        assert_eq!(SwaphiConfig::from_raw(&raw).unwrap().mode, SearchMode::Auto);
        // strict validation: the error names the key and the valid set
        raw.set("search.mode", "nope").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("mode"), "{err}");
        assert!(err.contains("exact|fast|auto"), "{err}");
    }

    #[test]
    fn report_key_parses_and_rejects() {
        let cfg = SwaphiConfig::default_config();
        assert_eq!(cfg.report, ReportLevel::Score, "score-only is the default");
        assert_eq!(cfg.report_cell_cap, 16_000_000);
        let mut raw = RawConfig::default();
        raw.set("search.report", "full").unwrap();
        raw.set("search.report_cell_cap", "1000").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.report, ReportLevel::Full);
        let sc = cfg.search_config();
        assert_eq!(sc.report, ReportLevel::Full);
        assert_eq!(sc.report_cell_cap, 1000);
        assert_eq!(sc.db_residues, 0, "config never claims a partition");
        raw.set("search.report", "coord").unwrap();
        assert_eq!(SwaphiConfig::from_raw(&raw).unwrap().report, ReportLevel::Coord);
        // strict validation: the error names the key and the valid set
        raw.set("search.report", "nope").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("report"), "{err}");
        assert!(err.contains("score|coord|full"), "{err}");
    }

    #[test]
    fn overrides_apply() {
        let mut raw = RawConfig::default();
        raw.set("search.engine", "intraqp").unwrap();
        raw.set("search.devices", "4").unwrap();
        raw.set("scoring.matrix", "PAM250").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.engine, EngineKind::IntraQP);
        assert_eq!(cfg.devices, 4);
        assert_eq!(cfg.scoring.name, "PAM250");
    }

    #[test]
    fn devices_section_wins_over_legacy_and_steal_parses() {
        let cfg = SwaphiConfig::default_config();
        assert_eq!(cfg.devices, 1);
        assert!(cfg.steal, "stealing defaults on");
        assert!(cfg.search_config().steal);

        let mut raw = RawConfig::default();
        raw.set("search.devices", "2").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.devices, 2, "legacy key still works alone");
        raw.set("devices.count", "4").unwrap();
        raw.set("devices.steal", "false").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.devices, 4, "devices.count is authoritative");
        assert!(!cfg.steal);
        let sc = cfg.search_config();
        assert_eq!(sc.devices, 4);
        assert!(!sc.steal);

        let parsed = RawConfig::parse("[devices]\ncount = 3\nsteal = true\n").unwrap();
        let cfg = SwaphiConfig::from_raw(&parsed).unwrap();
        assert_eq!(cfg.devices, 3);
        assert!(cfg.steal);
    }

    #[test]
    fn rates_list_parses_and_infers_device_count() {
        let raw = RawConfig::parse("[devices]\nrates = [1.0, 1.0, 0.25]\n").unwrap();
        assert_eq!(
            raw.get("devices.rates"),
            Some(&Value::List(vec![
                Value::Float(1.0),
                Value::Float(1.0),
                Value::Float(0.25)
            ]))
        );
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.devices, 3, "rates imply the device count");
        assert_eq!(cfg.rates, vec![1.0, 1.0, 0.25]);
        let sc = cfg.search_config();
        assert_eq!(sc.devices, 3);
        assert_eq!(sc.rates, vec![1.0, 1.0, 0.25]);
        assert_eq!(sc.device_rates(), vec![1.0, 1.0, 0.25]);
        // integers widen; explicit matching count is accepted
        let raw =
            RawConfig::parse("[devices]\ncount = 2\nrates = [1, 0.5]\n").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.devices, 2);
        assert_eq!(cfg.rates, vec![1.0, 0.5]);
        // no rates -> uniform fleet materialized on demand
        let cfg = SwaphiConfig::default_config();
        assert!(cfg.rates.is_empty());
        assert_eq!(cfg.search_config().device_rates(), vec![1.0]);
    }

    #[test]
    fn rates_mismatch_and_bad_entries_rejected() {
        let raw = RawConfig::parse("[devices]\ncount = 3\nrates = [1.0, 0.5]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("2 entries"), "{err}");
        let raw = RawConfig::parse("[devices]\nrates = [1.0, 0.0]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("positive"), "{err}");
        let raw = RawConfig::parse("[devices]\nrates = [1.0, -2.0]\n").unwrap();
        assert!(SwaphiConfig::from_raw(&raw).is_err());
        let raw = RawConfig::parse("[devices]\nrates = [true]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("expected number"), "{err}");
        let raw = RawConfig::parse("[devices]\nrates = 4\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("expected list"), "{err}");
        assert!(RawConfig::parse("[devices]\nrates = [1.0, 0.5\n").is_err());
    }

    #[test]
    fn empty_list_value_parses() {
        let raw = RawConfig::parse("[devices]\nrates = []\n").unwrap();
        assert_eq!(raw.get("devices.rates"), Some(&Value::List(Vec::new())));
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert!(cfg.rates.is_empty());
        assert_eq!(cfg.devices, 1);
        // whitespace-only interior is the empty list too
        let raw = RawConfig::parse("[devices]\nrates = [   ]\n").unwrap();
        assert_eq!(raw.get("devices.rates"), Some(&Value::List(Vec::new())));
    }

    #[test]
    fn list_trailing_or_doubled_comma_names_the_position() {
        let err = RawConfig::parse("[devices]\nrates = [1.0, 0.5,]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("position 3"), "{err}");
        assert!(err.contains("trailing or doubled comma"), "{err}");
        let err = RawConfig::parse("[devices]\nrates = [1.0,, 0.5]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("position 2"), "{err}");
        // a bad element inside the list names its position and spelling
        let err = RawConfig::parse("[devices]\nrates = [1.0, 2..5]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("element 2"), "{err}");
        assert!(err.contains("2..5"), "{err}");
    }

    #[test]
    fn list_whitespace_is_forgiven_and_comments_stripped() {
        let raw = RawConfig::parse("[devices]\nrates = [  1.0 ,\t0.5  ]  # fleet\n").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.rates, vec![1.0, 0.5]);
        assert_eq!(cfg.devices, 2);
    }

    #[test]
    fn list_mixed_int_float_coerces_and_unterminated_errors() {
        let raw = RawConfig::parse("[devices]\nrates = [2, 0.5, 1]\n").unwrap();
        assert_eq!(
            raw.get("devices.rates"),
            Some(&Value::List(vec![Value::Int(2), Value::Float(0.5), Value::Int(1)]))
        );
        assert_eq!(
            raw.f64_list_or("devices.rates", &[]).unwrap(),
            vec![2.0, 0.5, 1.0],
            "integers widen to float in numeric lists"
        );
        let err = RawConfig::parse("[devices]\nrates = [1.0, 0.5\n").unwrap_err().to_string();
        assert!(err.contains("unterminated list"), "{err}");
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn rates_nan_and_zero_entries_name_entry_and_position() {
        // "nan" parses as an f64 — the semantic validator must name it
        let raw = RawConfig::parse("[devices]\nrates = [1.0, nan]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("devices.rates[2]"), "{err}");
        assert!(err.contains("NaN"), "{err}");
        assert!(err.contains("finite"), "{err}");
        let raw = RawConfig::parse("[devices]\nrates = [0.0, 1.0]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("devices.rates[1]"), "{err}");
        assert!(err.contains("positive"), "{err}");
        let raw = RawConfig::parse("[devices]\nrates = [1.0, inf]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("devices.rates[2]"), "{err}");
    }

    #[test]
    fn tune_section_parses_with_defaults_and_validates() {
        let cfg = SwaphiConfig::default_config();
        assert!(!cfg.tune_enabled, "calibration is opt-in");
        assert_eq!(cfg.tune_warmup_batches, 3);
        assert!((cfg.tune_ewma_alpha - 0.3).abs() < 1e-12);
        assert!((cfg.tune_dead_band - 0.15).abs() < 1e-12);
        assert_eq!(cfg.tune_min_batches_between_reshards, 2);
        let tc = cfg.tune_config();
        assert!(!tc.enabled);
        assert!(!cfg.search_config().tune.enabled);

        let raw = RawConfig::parse(
            "[tune]\nenabled = true\nwarmup_batches = 5\newma_alpha = 0.5\n\
             dead_band = 0.2\nmin_batches_between_reshards = 4\n",
        )
        .unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        let tc = cfg.tune_config();
        assert!(tc.enabled);
        assert_eq!(tc.warmup_batches, 5);
        assert!((tc.ewma_alpha - 0.5).abs() < 1e-12);
        assert!((tc.dead_band - 0.2).abs() < 1e-12);
        assert_eq!(tc.min_batches_between_reshards, 4);
        assert!(cfg.search_config().tune.enabled);

        for bad in ["[tune]\newma_alpha = 0.0\n", "[tune]\newma_alpha = 1.5\n"] {
            let raw = RawConfig::parse(bad).unwrap();
            let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
            assert!(err.contains("ewma_alpha"), "{err}");
        }
        let raw = RawConfig::parse("[tune]\ndead_band = -0.1\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("dead_band"), "{err}");
    }

    #[test]
    fn handicap_parses_and_validates() {
        let raw = RawConfig::parse("[devices]\ncount = 2\nhandicap = [1.0, 4.0]\n").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert_eq!(cfg.handicap, vec![1.0, 4.0]);
        assert_eq!(cfg.search_config().handicap, vec![1.0, 4.0]);
        // handicaps are slowdown multipliers: < 1.0 is rejected by name
        let raw = RawConfig::parse("[devices]\ncount = 2\nhandicap = [1.0, 0.5]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("devices.handicap[2]"), "{err}");
        // length must match the fleet
        let raw = RawConfig::parse("[devices]\ncount = 3\nhandicap = [1.0, 2.0]\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("2 entries"), "{err}");
    }

    #[test]
    fn unknown_key_rejected() {
        let raw = RawConfig::parse("[search]\nenginee = \"sp\"\n").unwrap();
        let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
        assert!(err.contains("enginee"), "{err}");
    }

    #[test]
    fn type_errors_reported() {
        let raw = RawConfig::parse("[search]\ndevices = \"four\"\n").unwrap();
        assert!(SwaphiConfig::from_raw(&raw).is_err());
    }

    #[test]
    fn bad_syntax_rejected() {
        assert!(RawConfig::parse("[unclosed\n").is_err());
        assert!(RawConfig::parse("no_equals_here\n").is_err());
        assert!(RawConfig::parse("k = \"unterminated\n").is_err());
    }

    #[test]
    fn search_config_materializes() {
        let mut raw = RawConfig::default();
        raw.set("search.devices", "4").unwrap();
        raw.set("sim.replication", "100").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        let sc = cfg.search_config();
        assert_eq!(sc.devices, 4);
        let sim = sc.sim.unwrap();
        assert_eq!(sim.devices, 4);
        assert_eq!(sim.replication, 100);
    }

    #[test]
    fn server_section_materializes() {
        let mut raw = RawConfig::default();
        raw.set("server.listen", "\"unix:/tmp/s.sock\"").unwrap();
        raw.set("server.queue_capacity", "64").unwrap();
        raw.set("server.max_batch", "8").unwrap();
        raw.set("server.batch_window_ms", "20").unwrap();
        raw.set("server.cache_entries", "0").unwrap();
        raw.set("server.slow_query_ms", "250").unwrap();
        raw.set("server.trace_ring", "0").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        let sc = cfg.server_config();
        assert_eq!(sc.listen, "unix:/tmp/s.sock");
        assert_eq!(sc.queue_capacity, 64);
        assert_eq!(sc.max_batch, 8);
        assert_eq!(sc.batch_window_ms, 20);
        assert_eq!(sc.cache_entries, 0);
        assert_eq!(sc.slow_query_ms, 250);
        assert_eq!(sc.trace_ring, 0, "trace ring can be disabled");
        assert!(!sc.handle_signals, "signals are the serve command's call");
        // defaults
        let d = SwaphiConfig::default_config().server_config();
        assert_eq!(d.listen, "127.0.0.1:7878");
        assert_eq!(d.cache_entries, 1024);
        assert_eq!(d.max_connections, 512);
        assert_eq!(d.slow_query_ms, 0, "slow-query log is off by default");
        assert_eq!(d.trace_ring, 4096, "span ring is on by default");
    }

    #[test]
    fn slo_and_flight_keys_materialize_and_validate() {
        // defaults: three nines, 2 s p99, recorder off
        let d = SwaphiConfig::default_config();
        let sc = d.server_config();
        assert!((sc.slo_availability - 0.999).abs() < 1e-12);
        assert_eq!(sc.slo_p99_ms, 2_000);
        assert_eq!(sc.flight_dir, None, "flight recorder is opt-in");
        assert_eq!(sc.flight_bundles, 8);
        let rc = d.router_config();
        assert!((rc.slo_availability - 0.999).abs() < 1e-12);
        assert_eq!(rc.flight_dir, None);

        let raw = RawConfig::parse(
            "[server]\nslo_availability = 0.99\nslo_p99_ms = 500\n\
             flight_dir = \"/tmp/flight\"\nflight_bundles = 3\n\
             [cluster]\nslo_availability = 0.9999\nflight_dir = \"/tmp/rf\"\n",
        )
        .unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        let sc = cfg.server_config();
        assert!((sc.slo_availability - 0.99).abs() < 1e-12);
        assert_eq!(sc.slo_p99_ms, 500);
        assert_eq!(sc.flight_dir.as_deref(), Some(std::path::Path::new("/tmp/flight")));
        assert_eq!(sc.flight_bundles, 3);
        let rc = cfg.router_config();
        assert!((rc.slo_availability - 0.9999).abs() < 1e-12);
        assert_eq!(rc.flight_dir.as_deref(), Some(std::path::Path::new("/tmp/rf")));

        // a 100% availability target has no error budget to burn
        for bad in ["1.0", "0.0", "-0.5", "nan"] {
            let mut raw = RawConfig::default();
            raw.set("server.slo_availability", bad).unwrap();
            let err = SwaphiConfig::from_raw(&raw).unwrap_err().to_string();
            assert!(err.contains("slo_availability"), "{bad}: {err}");
        }
    }

    #[test]
    fn sim_can_be_disabled() {
        let mut raw = RawConfig::default();
        raw.set("sim.enabled", "false").unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        assert!(cfg.search_config().sim.is_none());
    }

    #[test]
    fn bare_identifier_values_are_strings() {
        let raw = RawConfig::parse("[search]\nengine = intersp\n").unwrap();
        assert_eq!(raw.get("search.engine"), Some(&Value::Str("intersp".into())));
    }

    #[test]
    fn cluster_section_materializes_router_config() {
        // defaults: no backends, auto hedging
        let d = SwaphiConfig::default_config();
        assert!(d.cluster_backends.is_empty());
        let rc = d.router_config();
        assert_eq!(rc.listen, "127.0.0.1:7900");
        assert_eq!(rc.hedge_ms, None, "hedge delay is auto by default");
        assert_eq!(rc.retries, 2);
        assert_eq!(rc.backend_timeout_ms, 10_000);
        assert!(!rc.handle_signals, "signals are the route command's call");

        // addresses contain ':' so they must be quoted strings
        let raw = RawConfig::parse(
            "[cluster]\nlisten = \"127.0.0.1:7900\"\n\
             backends = [\"127.0.0.1:7901\", \"127.0.0.1:7902\"]\n\
             hedge_ms = 40\nretries = 1\nbackend_timeout_ms = 2000\n",
        )
        .unwrap();
        let cfg = SwaphiConfig::from_raw(&raw).unwrap();
        let rc = cfg.router_config();
        assert_eq!(rc.backends, vec!["127.0.0.1:7901", "127.0.0.1:7902"]);
        assert_eq!(rc.hedge_ms, Some(40));
        assert_eq!(rc.retries, 1);
        assert_eq!(rc.backend_timeout_ms, 2000);
    }

    #[test]
    fn str_list_rejects_non_string_elements_and_bare_addresses() {
        let raw = RawConfig::parse("[cluster]\nbackends = [7901, 7902]\n").unwrap();
        let err = raw.str_list_or("cluster.backends", &[]).unwrap_err().to_string();
        assert!(err.contains("expected string in list"), "{err}");
        // an unquoted socket address is a parse error, not a silent string
        assert!(RawConfig::parse("[cluster]\nbackends = [127.0.0.1:7901]\n").is_err());
        // default pass-through
        assert_eq!(
            RawConfig::default().str_list_or("cluster.backends", &["a", "b"]).unwrap(),
            vec!["a".to_string(), "b".to_string()]
        );
    }
}

//! Online rate calibration — the self-tuning fleet subsystem.
//!
//! PR 4 made the fleet rate-*aware*: shards are weighted by
//! `padded_residues ÷ rate` and steal victims picked by estimated
//! remaining time. But the rates themselves were still static operator
//! config, which the paper itself concedes is fragile (its dynamic
//! intra-task distribution exists precisely because static splits
//! mis-model real devices), and Rucci et al. (PAPERS.md) measure
//! sustained SW throughput shifting materially with thread placement and
//! memory mode. This module converts the whole rate surface from input
//! to output:
//!
//! * [`estimator::RateEstimator`] — per-device EWMA throughput (padded
//!   cells per second), fed by the device layer's timing hooks
//!   (`coordinator::devices` — items are timed individually and folded
//!   once per device per batch, so the hot loop takes no calibration
//!   locks) and, in simulation, by the deterministic clocks of
//!   `phi::sim::simulate_calibrated_search`;
//! * [`policy::DriftPolicy`] — warmup-window adoption plus dead-band
//!   drift detection (calibrated ÷ adopted outside the band for
//!   [`policy::DRIFT_BATCHES`] consecutive batches), rate-limited by
//!   `min_batches_between_reshards`;
//! * [`Tuner`] — the thread-safe facade both of them live behind: device
//!   host threads call [`Tuner::observe`] concurrently, the session
//!   calls [`Tuner::end_batch`] at the barrier, and a returned vector
//!   means "re-shard to these rates **now**, at the barrier" — never
//!   mid-batch, so scatter–gather completeness and result bit-identity
//!   are untouched by construction.

pub mod estimator;
pub mod policy;

pub use estimator::RateEstimator;
pub use policy::{Decision, DriftPolicy, TuneConfig, DRIFT_BATCHES};

use std::sync::Mutex;

/// The canonical calibration probe batch: `n` seeded synthetic queries
/// of length `qlen`, used by both the daemon's warmup window (index
/// load) and the offline `swaphi calibrate` command — one probe shape,
/// so the two calibration paths can never silently diverge. Probe
/// results are always discarded; probes must never touch caches or
/// request metrics.
pub fn probe_batch(qlen: usize, n: usize) -> Vec<(String, Vec<u8>)> {
    let qlen = qlen.max(16);
    (0..n)
        .map(|i| {
            (
                format!("calibration-probe-{i}"),
                crate::db::synth::generate_query(qlen, 0xCA11_B8A7E ^ i as u64),
            )
        })
        .collect()
}

/// Point-in-time calibration state of one device (for `stats` and the
/// CLI's calibration report).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TuneGauge {
    pub device: usize,
    /// The operator-supplied rate this device started with.
    pub configured: f64,
    /// The estimator's current normalized rate (falls back to the
    /// adopted rate until this device has been observed).
    pub calibrated: f64,
    /// The rate the fleet currently runs on (configured until the first
    /// adoption).
    pub adopted: f64,
}

struct TunerState {
    estimator: RateEstimator,
    policy: DriftPolicy,
}

/// Thread-safe calibration facade shared by the device host threads (who
/// time work items), the session (who asks for a re-shard decision at
/// each batch barrier) and observers (the server's `stats` op).
pub struct Tuner {
    cfg: TuneConfig,
    configured: Vec<f64>,
    state: Mutex<TunerState>,
}

impl Tuner {
    pub fn new(configured_rates: &[f64], cfg: TuneConfig) -> Tuner {
        cfg.validate();
        assert!(!configured_rates.is_empty(), "need at least one device");
        Tuner {
            configured: configured_rates.to_vec(),
            state: Mutex::new(TunerState {
                estimator: RateEstimator::new(configured_rates.len(), cfg.ewma_alpha),
                policy: DriftPolicy::new(configured_rates.to_vec(), cfg.clone()),
            }),
            cfg,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.configured.len()
    }

    pub fn config(&self) -> &TuneConfig {
        &self.cfg
    }

    /// The operator-supplied rate vector.
    pub fn configured(&self) -> &[f64] {
        &self.configured
    }

    /// Fold one timed observation: device `dev` spent `seconds`
    /// processing `padded_cells` DP cells. Called concurrently from the
    /// device host threads.
    pub fn observe(&self, dev: usize, padded_cells: f64, seconds: f64) {
        self.state.lock().unwrap().estimator.observe(dev, padded_cells, seconds);
    }

    /// Batch barrier: feed the policy and return the rate vector to
    /// re-shard to, if drift (or the warmup boundary) demands one.
    /// Devices that have never been observed (empty shard, stealing
    /// off) hold their adopted rate as a prior instead of starving the
    /// whole loop — see [`RateEstimator::calibrated_with_prior`].
    pub fn end_batch(&self) -> Option<Vec<f64>> {
        let mut st = self.state.lock().unwrap();
        let target_sum: f64 = self.configured.iter().sum();
        let cal = st.estimator.calibrated_with_prior(st.policy.adopted(), target_sum);
        match st.policy.end_batch(cal.as_deref()) {
            Decision::Hold => None,
            Decision::Adopt(rates) => Some(rates),
        }
    }

    /// Batches folded so far.
    pub fn batches(&self) -> u64 {
        self.state.lock().unwrap().policy.batches()
    }

    /// Rate vectors adopted so far (== re-shards recommended).
    pub fn adoptions(&self) -> u64 {
        self.state.lock().unwrap().policy.adoptions()
    }

    /// The rates the fleet currently runs on.
    pub fn adopted(&self) -> Vec<f64> {
        self.state.lock().unwrap().policy.adopted().to_vec()
    }

    /// Current calibrated estimate (normalized to the configured sum);
    /// unobserved devices hold their adopted rate as a prior, and the
    /// whole vector falls back to the adopted one while nothing has
    /// been observed at all.
    pub fn calibrated(&self) -> Vec<f64> {
        let st = self.state.lock().unwrap();
        let target_sum: f64 = self.configured.iter().sum();
        st.estimator
            .calibrated_with_prior(st.policy.adopted(), target_sum)
            .unwrap_or_else(|| st.policy.adopted().to_vec())
    }

    /// Per-device configured / calibrated / adopted gauges.
    pub fn gauges(&self) -> Vec<TuneGauge> {
        let st = self.state.lock().unwrap();
        let target_sum: f64 = self.configured.iter().sum();
        let cal = st.estimator.calibrated_with_prior(st.policy.adopted(), target_sum);
        let adopted = st.policy.adopted();
        (0..self.configured.len())
            .map(|d| TuneGauge {
                device: d,
                configured: self.configured[d],
                calibrated: cal.as_ref().map_or(adopted[d], |c| c[d]),
                adopted: adopted[d],
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuner(configured: &[f64], warmup: u64) -> Tuner {
        Tuner::new(
            configured,
            TuneConfig {
                enabled: true,
                warmup_batches: warmup,
                ewma_alpha: 0.5,
                dead_band: 0.15,
                min_batches_between_reshards: 2,
            },
        )
    }

    /// One simulated batch where device d's true speed is `speed[d]`
    /// (cells per second), each processing the same cell count.
    fn feed(t: &Tuner, speeds: &[f64]) {
        for (d, &s) in speeds.iter().enumerate() {
            t.observe(d, 1000.0, 1000.0 / s);
        }
    }

    #[test]
    fn miscalibrated_fleet_reweights_at_warmup() {
        let t = tuner(&[1.0, 1.0, 1.0], 2);
        let truth = [400.0, 400.0, 100.0];
        feed(&t, &truth);
        assert_eq!(t.end_batch(), None, "warmup batch 1 holds");
        feed(&t, &truth);
        let rates = t.end_batch().expect("warmup boundary must adopt");
        assert_eq!(t.adoptions(), 1);
        // normalized to the configured sum (3.0), ratios match the truth
        assert!((rates.iter().sum::<f64>() - 3.0).abs() < 1e-9);
        assert!((rates[0] / rates[2] - 4.0).abs() < 1e-6, "{rates:?}");
        assert_eq!(t.adopted(), rates);
        // steady state: same truth, no further re-shards
        for _ in 0..5 {
            feed(&t, &truth);
            assert_eq!(t.end_batch(), None);
        }
        assert_eq!(t.adoptions(), 1);
    }

    #[test]
    fn mid_run_drift_triggers_reshard_after_streak() {
        let t = tuner(&[1.0, 1.0], 1);
        let uniform = [500.0, 500.0];
        let skewed = [500.0, 125.0];
        for _ in 0..3 {
            feed(&t, &uniform);
            assert_eq!(t.end_batch(), None, "well-calibrated fleet holds");
        }
        // the device slows down mid-run: EWMA needs a couple of batches
        // to move the estimate out of the dead-band, then the streak
        // (DRIFT_BATCHES) must fill before adoption
        let mut resharded_at = None;
        for b in 0..6 {
            feed(&t, &skewed);
            if let Some(rates) = t.end_batch() {
                resharded_at = Some(b);
                assert!(rates[1] < rates[0] * 0.5, "{rates:?}");
                break;
            }
        }
        let b = resharded_at.expect("sustained drift must trigger a re-shard");
        assert!(b >= 1, "a single out-of-band batch must not re-shard");
        assert_eq!(t.adoptions(), 1);
    }

    #[test]
    fn partially_observed_fleet_still_calibrates() {
        // device 2 never executes an item (empty shard, stealing off):
        // the observed pair's skew must still be adopted, with the
        // unobserved device holding its prior relative rate
        let t = tuner(&[1.0, 1.0, 1.0], 1);
        t.observe(0, 1000.0, 1.0);
        t.observe(1, 1000.0, 4.0);
        let rates = t.end_batch().expect("observed skew must adopt despite a silent device");
        assert!(rates[1] < rates[0] / 2.0, "{rates:?}");
        // unobserved device kept the prior (== mean of observed priors
        // in measured units): between the fast and slow measured rates
        assert!(rates[1] < rates[2] && rates[2] < rates[0], "{rates:?}");
        assert_eq!(t.adoptions(), 1);
    }

    #[test]
    fn gauges_report_all_three_rate_surfaces() {
        let t = tuner(&[1.0, 1.0], 1);
        let g = t.gauges();
        assert_eq!(g.len(), 2);
        assert_eq!(g[0].configured, 1.0);
        assert_eq!(g[0].calibrated, 1.0, "unobserved falls back to adopted");
        feed(&t, &[600.0, 200.0]);
        let rates = t.end_batch().expect("warmup 1 adopts immediately");
        let g = t.gauges();
        assert!((g[0].calibrated - 1.5).abs() < 1e-9, "{g:?}");
        assert!((g[1].calibrated - 0.5).abs() < 1e-9, "{g:?}");
        assert_eq!(g[0].adopted, rates[0]);
        assert_eq!(g[1].configured, 1.0, "configured never changes");
        assert_eq!(t.calibrated(), rates);
    }
}

//! The re-shard decision policy — the *control* half of online rate
//! calibration.
//!
//! SWAPHI's scale step assumes the operator knows each coprocessor's
//! speed; this policy closes the loop the paper leaves to static
//! configuration. It runs at batch barriers only (never mid-batch, so
//! the scatter–gather completeness guard and result bit-identity are
//! untouched) and moves through two phases:
//!
//! 1. **warmup** — for the first `warmup_batches` batches the estimator
//!    just accumulates; at the warmup boundary the measured vector is
//!    adopted outright if it sits outside the dead-band of the
//!    configured one (the "configured `[1,1,1]`, truly `[1,1,0.25]`"
//!    case re-weights here);
//! 2. **steady state** — drift is declared when any device's
//!    calibrated ÷ adopted rate ratio leaves the dead-band for
//!    [`DRIFT_BATCHES`] *consecutive* batches (one slow batch is noise;
//!    a streak is a slow device), and a re-shard is recommended no more
//!    often than every `min_batches_between_reshards` batches — the
//!    hysteresis that keeps a fleet from thrashing between two nearly
//!    equivalent splits.

/// Consecutive out-of-band batches required to declare drift (K). One
/// batch of noise must not trigger a re-shard; K ≥ 2 means a sustained
/// shift does, within K batches of its onset.
pub const DRIFT_BATCHES: u64 = 2;

/// The `[tune]` config section: knobs of the self-calibration loop.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneConfig {
    /// Master switch; off = the fleet stays exactly as configured
    /// (PR-4 behaviour).
    pub enabled: bool,
    /// Batches of pure measurement before the first adoption.
    pub warmup_batches: u64,
    /// EWMA weight of the newest throughput observation, in (0, 1].
    pub ewma_alpha: f64,
    /// Relative dead-band around 1.0 for the calibrated ÷ adopted ratio;
    /// inside it the fleet is considered correctly weighted.
    pub dead_band: f64,
    /// Re-shard rate limit: at least this many batches between adoptions.
    pub min_batches_between_reshards: u64,
}

impl Default for TuneConfig {
    fn default() -> TuneConfig {
        TuneConfig {
            enabled: false,
            warmup_batches: 3,
            ewma_alpha: 0.3,
            dead_band: 0.15,
            min_batches_between_reshards: 2,
        }
    }
}

impl TuneConfig {
    /// Panic on nonsensical knob values (the config layer validates with
    /// errors; this is the library-level contract).
    pub fn validate(&self) {
        assert!(
            self.ewma_alpha.is_finite() && self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0,
            "tune.ewma_alpha must be in (0, 1], got {}",
            self.ewma_alpha
        );
        assert!(
            self.dead_band.is_finite() && self.dead_band > 0.0,
            "tune.dead_band must be positive, got {}",
            self.dead_band
        );
    }
}

/// What the policy decided at a batch barrier.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Keep the current shards.
    Hold,
    /// Re-shard to this rate vector at the barrier.
    Adopt(Vec<f64>),
}

/// Batch-barrier drift detector. Pure state machine: feed it the
/// calibrated vector each batch, it answers hold/adopt.
#[derive(Clone, Debug)]
pub struct DriftPolicy {
    cfg: TuneConfig,
    /// The rate vector the fleet currently runs on (configured until the
    /// first adoption).
    adopted: Vec<f64>,
    batches: u64,
    warmed: bool,
    drift_streak: u64,
    last_adoption: u64,
    adoptions: u64,
}

impl DriftPolicy {
    pub fn new(configured: Vec<f64>, cfg: TuneConfig) -> DriftPolicy {
        cfg.validate();
        assert!(!configured.is_empty(), "need at least one configured rate");
        DriftPolicy {
            cfg,
            adopted: configured,
            batches: 0,
            warmed: false,
            drift_streak: 0,
            last_adoption: 0,
            adoptions: 0,
        }
    }

    /// Rates the fleet currently runs on.
    pub fn adopted(&self) -> &[f64] {
        &self.adopted
    }

    pub fn batches(&self) -> u64 {
        self.batches
    }

    pub fn adoptions(&self) -> u64 {
        self.adoptions
    }

    /// Is `calibrated` within the dead-band of the adopted vector on
    /// every device?
    fn in_band(&self, calibrated: &[f64]) -> bool {
        calibrated.iter().zip(&self.adopted).all(|(&c, &a)| {
            let ratio = c / a;
            ratio >= 1.0 - self.cfg.dead_band && ratio <= 1.0 + self.cfg.dead_band
        })
    }

    fn adopt(&mut self, calibrated: Vec<f64>) -> Decision {
        self.adopted = calibrated.clone();
        self.last_adoption = self.batches;
        self.adoptions += 1;
        self.drift_streak = 0;
        Decision::Adopt(calibrated)
    }

    /// One batch finished; `calibrated` is the estimator's current
    /// normalized vector (`None` while some device is still unobserved).
    pub fn end_batch(&mut self, calibrated: Option<&[f64]>) -> Decision {
        self.batches += 1;
        let Some(cal) = calibrated else { return Decision::Hold };
        debug_assert_eq!(cal.len(), self.adopted.len());
        if self.batches < self.cfg.warmup_batches {
            return Decision::Hold;
        }
        if !self.warmed {
            // warmup boundary: adopt outright if the configured rates
            // were materially wrong
            self.warmed = true;
            if self.in_band(cal) {
                return Decision::Hold;
            }
            return self.adopt(cal.to_vec());
        }
        if self.in_band(cal) {
            self.drift_streak = 0;
            return Decision::Hold;
        }
        self.drift_streak += 1;
        if self.drift_streak >= DRIFT_BATCHES
            && self.batches - self.last_adoption >= self.cfg.min_batches_between_reshards
        {
            return self.adopt(cal.to_vec());
        }
        Decision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TuneConfig {
        TuneConfig {
            enabled: true,
            warmup_batches: 2,
            ewma_alpha: 0.5,
            dead_band: 0.15,
            min_batches_between_reshards: 2,
        }
    }

    #[test]
    fn warmup_adopts_miscalibrated_rates_exactly_at_boundary() {
        let mut p = DriftPolicy::new(vec![1.0, 1.0, 1.0], cfg());
        let skew = vec![4.0 / 3.0, 4.0 / 3.0, 1.0 / 3.0];
        assert_eq!(p.end_batch(Some(&skew)), Decision::Hold, "batch 1 is warmup");
        assert_eq!(p.end_batch(Some(&skew)), Decision::Adopt(skew.clone()), "batch 2 adopts");
        assert_eq!(p.adopted(), &skew[..]);
        assert_eq!(p.adoptions(), 1);
        // steady state thereafter: the adopted vector is now in-band
        assert_eq!(p.end_batch(Some(&skew)), Decision::Hold);
    }

    #[test]
    fn warmup_holds_when_configured_rates_are_right() {
        let mut p = DriftPolicy::new(vec![1.0, 1.0], cfg());
        let near = vec![1.05, 0.95]; // inside the 15% band
        assert_eq!(p.end_batch(Some(&near)), Decision::Hold);
        assert_eq!(p.end_batch(Some(&near)), Decision::Hold, "in-band warmup never adopts");
        assert_eq!(p.adoptions(), 0);
        assert_eq!(p.adopted(), &[1.0, 1.0]);
    }

    #[test]
    fn drift_needs_a_streak_not_one_noisy_batch() {
        let mut p = DriftPolicy::new(vec![1.0, 1.0], cfg());
        let near = vec![1.0, 1.0];
        let skew = vec![1.5, 0.5];
        p.end_batch(Some(&near));
        p.end_batch(Some(&near)); // warmed, no adoption
        assert_eq!(p.end_batch(Some(&skew)), Decision::Hold, "streak 1 of 2");
        assert_eq!(p.end_batch(Some(&near)), Decision::Hold, "noise resets the streak");
        assert_eq!(p.end_batch(Some(&skew)), Decision::Hold);
        assert_eq!(p.end_batch(Some(&skew)), Decision::Adopt(skew.clone()), "sustained drift");
    }

    #[test]
    fn reshards_are_rate_limited() {
        let mut p = DriftPolicy::new(
            vec![1.0],
            TuneConfig { min_batches_between_reshards: 4, ..cfg() },
        );
        let a = vec![1.0];
        let b = vec![0.5];
        let c = vec![2.0];
        p.end_batch(Some(&a));
        p.end_batch(Some(&a)); // warmed, in band
        // sustained drift toward b: streak of 2 reached at batch 4, but
        // last_adoption = 0 so 4 - 0 >= 4 allows it
        assert_eq!(p.end_batch(Some(&b)), Decision::Hold);
        assert_eq!(p.end_batch(Some(&b)), Decision::Adopt(b.clone()));
        // immediately drift again toward c: streak reaches 2 at batch 6,
        // but 6 - 4 < 4 — rate limit holds it until batch 8
        assert_eq!(p.end_batch(Some(&c)), Decision::Hold);
        assert_eq!(p.end_batch(Some(&c)), Decision::Hold, "streak met, rate limit blocks");
        assert_eq!(p.end_batch(Some(&c)), Decision::Hold);
        assert_eq!(p.end_batch(Some(&c)), Decision::Adopt(c.clone()));
    }

    #[test]
    fn unready_estimator_always_holds() {
        let mut p = DriftPolicy::new(vec![1.0, 1.0], cfg());
        for _ in 0..10 {
            assert_eq!(p.end_batch(None), Decision::Hold);
        }
        assert_eq!(p.adoptions(), 0);
        // readiness arriving late hits the (long past) warmup boundary
        // and adopts outright
        let skew = vec![1.6, 0.4];
        assert_eq!(p.end_batch(Some(&skew)), Decision::Adopt(skew));
    }

    #[test]
    fn zero_warmup_adopts_on_first_batch() {
        let mut p = DriftPolicy::new(vec![1.0, 1.0], TuneConfig { warmup_batches: 0, ..cfg() });
        let skew = vec![1.5, 0.5];
        assert_eq!(p.end_batch(Some(&skew)), Decision::Adopt(skew));
    }

    #[test]
    #[should_panic(expected = "dead_band")]
    fn bad_dead_band_rejected() {
        DriftPolicy::new(vec![1.0], TuneConfig { dead_band: 0.0, ..cfg() });
    }
}

//! Per-device throughput estimation — the *measurement* half of online
//! rate calibration.
//!
//! Every timed work item (or, in simulation, every deterministic batch)
//! contributes one observation per device: padded cells processed and
//! the seconds it took. The estimator folds observations into an
//! exponentially-weighted moving average of instantaneous throughput
//! (padded cells per second), so recent behaviour dominates but a single
//! noisy item cannot whip the estimate around. Rucci et al.'s KNL study
//! (PAPERS.md) is the motivation: sustained SW throughput is a measured,
//! drifting quantity — thread placement, memory mode and co-tenancy all
//! move it — so treating the rate vector as static config mis-models
//! real fleets.
//!
//! The estimator is deliberately unit-agnostic: it reports *relative*
//! rates (normalized so the vector sums like the configured one), which
//! is all the weighted partitioner and the steal policy consume — both
//! are invariant under uniform rescaling of the rate vector.

/// EWMA throughput state of one device.
#[derive(Clone, Copy, Debug, Default)]
struct DeviceEwma {
    /// Smoothed throughput (padded cells / second); meaningful only when
    /// `observations > 0`.
    rate: f64,
    observations: u64,
}

/// Per-device EWMA throughput estimator (padded cells per second).
#[derive(Clone, Debug)]
pub struct RateEstimator {
    alpha: f64,
    devices: Vec<DeviceEwma>,
}

impl RateEstimator {
    /// `alpha` is the EWMA weight of the newest observation, in (0, 1].
    pub fn new(n_devices: usize, alpha: f64) -> RateEstimator {
        assert!(n_devices >= 1, "need at least one device");
        assert!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "ewma alpha must be in (0, 1], got {alpha}"
        );
        RateEstimator { alpha, devices: vec![DeviceEwma::default(); n_devices] }
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Fold one observation: device `dev` processed `padded_cells` in
    /// `seconds`. Non-positive or non-finite inputs are ignored (a
    /// zero-length timing window carries no rate information).
    pub fn observe(&mut self, dev: usize, padded_cells: f64, seconds: f64) {
        if !(padded_cells > 0.0) || !(seconds > 0.0) || !seconds.is_finite() {
            return;
        }
        let inst = padded_cells / seconds;
        if !inst.is_finite() {
            return;
        }
        let d = &mut self.devices[dev];
        d.rate = if d.observations == 0 {
            inst
        } else {
            self.alpha * inst + (1.0 - self.alpha) * d.rate
        };
        d.observations += 1;
    }

    /// Observations folded into device `dev` so far.
    pub fn observations(&self, dev: usize) -> u64 {
        self.devices[dev].observations
    }

    /// True once every device has at least one observation — before that
    /// there is no complete vector to calibrate from.
    pub fn ready(&self) -> bool {
        self.devices.iter().all(|d| d.observations > 0)
    }

    /// Raw EWMA throughput of one device (cells/s); `None` before its
    /// first observation.
    pub fn throughput(&self, dev: usize) -> Option<f64> {
        let d = self.devices[dev];
        (d.observations > 0).then_some(d.rate)
    }

    /// The calibrated relative-rate vector: measured throughputs scaled
    /// so the vector sums to `target_sum` (callers pass the configured
    /// vector's sum so calibrated and configured rates are directly
    /// comparable per device). `None` until [`ready`](Self::ready).
    pub fn calibrated(&self, target_sum: f64) -> Option<Vec<f64>> {
        if !self.ready() {
            return None;
        }
        let total: f64 = self.devices.iter().map(|d| d.rate).sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        Some(self.devices.iter().map(|d| d.rate * target_sum / total).collect())
    }

    /// Like [`calibrated`](Self::calibrated), but devices with no
    /// observations hold their `prior` rate *relative to the observed
    /// devices' priors* instead of blocking the whole vector — so a
    /// device that never executes an item (empty shard, stealing off)
    /// cannot starve calibration for the rest of the fleet. `None` only
    /// when **no** device has been observed.
    pub fn calibrated_with_prior(&self, prior: &[f64], target_sum: f64) -> Option<Vec<f64>> {
        assert_eq!(prior.len(), self.devices.len(), "one prior rate per device");
        if self.ready() {
            return self.calibrated(target_sum);
        }
        let obs_rate: f64 =
            self.devices.iter().filter(|d| d.observations > 0).map(|d| d.rate).sum();
        let obs_prior: f64 = self
            .devices
            .iter()
            .zip(prior)
            .filter(|(d, _)| d.observations > 0)
            .map(|(_, &p)| p)
            .sum();
        if !(obs_rate > 0.0) || !obs_rate.is_finite() || !(obs_prior > 0.0) {
            return None;
        }
        // unobserved devices: no information, so keep the prior belief —
        // scaled into the measured units via the observed devices
        let scale = obs_rate / obs_prior;
        let est: Vec<f64> = self
            .devices
            .iter()
            .zip(prior)
            .map(|(d, &p)| if d.observations > 0 { d.rate } else { p * scale })
            .collect();
        let total: f64 = est.iter().sum();
        Some(est.iter().map(|&r| r * target_sum / total).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_seeds_then_ewma_blends() {
        let mut e = RateEstimator::new(2, 0.5);
        assert!(!e.ready());
        assert_eq!(e.throughput(0), None);
        e.observe(0, 100.0, 1.0); // 100 cells/s
        assert_eq!(e.throughput(0), Some(100.0));
        e.observe(0, 300.0, 1.0); // inst 300 -> 0.5*300 + 0.5*100 = 200
        assert_eq!(e.throughput(0), Some(200.0));
        assert_eq!(e.observations(0), 2);
        assert!(!e.ready(), "device 1 unobserved");
        e.observe(1, 50.0, 1.0);
        assert!(e.ready());
    }

    #[test]
    fn calibrated_normalizes_to_target_sum() {
        let mut e = RateEstimator::new(3, 1.0);
        e.observe(0, 400.0, 1.0);
        e.observe(1, 400.0, 1.0);
        e.observe(2, 100.0, 1.0); // quarter-rate straggler
        let cal = e.calibrated(3.0).unwrap();
        assert!((cal.iter().sum::<f64>() - 3.0).abs() < 1e-12);
        assert!((cal[0] - cal[1]).abs() < 1e-12);
        assert!((cal[0] / cal[2] - 4.0).abs() < 1e-9, "{cal:?}");
    }

    #[test]
    fn degenerate_observations_are_ignored() {
        let mut e = RateEstimator::new(1, 0.3);
        e.observe(0, 100.0, 0.0);
        e.observe(0, 0.0, 1.0);
        e.observe(0, 100.0, f64::NAN);
        e.observe(0, 100.0, f64::INFINITY);
        assert_eq!(e.observations(0), 0);
        assert!(e.calibrated(1.0).is_none());
        e.observe(0, 100.0, 2.0);
        assert_eq!(e.throughput(0), Some(50.0));
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn alpha_out_of_range_rejected() {
        let _ = RateEstimator::new(2, 0.0);
    }

    #[test]
    fn unobserved_devices_hold_their_prior_instead_of_starving() {
        let mut e = RateEstimator::new(3, 1.0);
        assert!(e.calibrated_with_prior(&[1.0, 1.0, 1.0], 3.0).is_none(), "nothing observed");
        // devices 0 and 1 observed (device 1 half speed); device 2 never
        // executes an item — it must keep its prior rate relative to the
        // observed pair, not block the vector
        e.observe(0, 400.0, 1.0);
        e.observe(1, 200.0, 1.0);
        let cal = e.calibrated_with_prior(&[1.0, 1.0, 1.0], 3.0).unwrap();
        assert!((cal.iter().sum::<f64>() - 3.0).abs() < 1e-12);
        assert!((cal[0] / cal[1] - 2.0).abs() < 1e-9, "{cal:?}");
        // unobserved device sits at the observed devices' prior mean:
        // est2 = 1.0 * (600/2) = 300, between the two measured rates
        assert!((cal[2] / cal[1] - 1.5).abs() < 1e-9, "{cal:?}");
        // once everyone is observed it is exactly `calibrated`
        e.observe(2, 100.0, 1.0);
        assert_eq!(
            e.calibrated_with_prior(&[1.0, 1.0, 1.0], 3.0),
            e.calibrated(3.0)
        );
    }
}
